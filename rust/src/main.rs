//! SAL-PIM command-line interface.
//!
//! Every command is declared as a [`cli::spec::CommandSpec`] flag table
//! (parsing, `--help` and the README CLI section are generated from it)
//! and executed through the [`scenario`] subsystem: the command builds a
//! typed [`Scenario`], the [`Runner`] returns a structured [`Outcome`],
//! and the sink layer renders it — text tables by default, `--json` for
//! the schema-versioned JSON record, `--out FILE` to also write it
//! (`.json` / `.csv` picked by extension).
//!
//! `sal-pim run --scenario scenarios/smoke.toml` executes a whole suite
//! from a file and accumulates the outcomes into `BENCH_<tag>.json`
//! trajectory files. Run `sal-pim help` for the command list and
//! `sal-pim <command> --help` for any flag table.

use sal_pim::cli::{spec, Args};
use sal_pim::scenario::{
    compare, file::parse_suite, parse_policy, parse_route, sink, AreaParams, BreakdownParams,
    ConfigSel, EngineKind, Outcome, PowerParams, Provenance, Runner, Scenario, ServeParams,
    SimulateParams, SweepParams,
};
use sal_pim::report::fmt_bw;
use sal_pim::serve::{
    BackendKind, EngineCore, EvictPolicy, FabricKind, KvPolicy, PrefixCacheMode, SchedSpec,
    WorkloadSpec,
};
use sal_pim::trace::{chrome_trace_json, PhaseProfile, TraceEvent};
use std::path::Path;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let mut argv = std::env::args().skip(1);
    let command = match argv.next() {
        None => {
            print!("{}", spec::usage());
            return Ok(());
        }
        Some(c) if c == "--help" || c == "-h" => {
            print!("{}", spec::usage());
            return Ok(());
        }
        Some(c) => c,
    };
    let Some(command_spec) = spec::find(&command) else {
        let commands = spec::commands();
        let suggestion =
            sal_pim::cli::suggest(&command, commands.iter().map(|c| c.name), "");
        anyhow::bail!("unknown command `{command}`{suggestion} — run `sal-pim help`");
    };
    let args = Args::parse_for(&command_spec, argv)?;
    if args.switch("help") {
        print!("{}", command_spec.help_text());
        return Ok(());
    }
    match command.as_str() {
        "config" => cmd_config(&args),
        "run" => cmd_run(&args),
        "compare" => cmd_compare(&args),
        "help" => {
            if args.switch("markdown") {
                print!("{}", spec::markdown());
            } else {
                print!("{}", spec::usage());
            }
            Ok(())
        }
        cmd => {
            let scenario = build_scenario(cmd, &args)?;
            if let Some(path) = args.flag("trace") {
                anyhow::ensure!(
                    Runner::traceable(&scenario),
                    "--trace needs --engine batch|cluster without --sweep \
                     (the seq coordinator and load sweeps emit no lifecycle trace)"
                );
                let (outcome, aux) = Runner::new().run_with(&scenario, true)?;
                write_trace(path, &aux.events)?;
                emit(&args, &outcome)
            } else {
                let outcome = Runner::new().run(&scenario)?;
                emit(&args, &outcome)
            }
        }
    }
}

/// Build the scenario one experiment command describes.
fn build_scenario(command: &str, args: &Args) -> anyhow::Result<Scenario> {
    let config = config_sel(args)?;
    match command {
        "simulate" => Ok(Scenario::Simulate(
            SimulateParams::default()
                .with_config(config)
                .with_io(args.get("in", 32usize)?, args.get("gen", 64usize)?)
                .with_prefetch(args.switch("prefetch")),
        )),
        "sweep" => Ok(Scenario::Sweep(SweepParams::default().with_config(config))),
        "breakdown" => Ok(Scenario::Breakdown(
            BreakdownParams::default()
                .with_config(config)
                .with_kv(args.get("kv", 128usize)?),
        )),
        "power" => Ok(Scenario::Power(
            PowerParams::default()
                .with_config(config)
                .with_io(32, args.get("gen", 32usize)?),
        )),
        "area" => Ok(Scenario::Area(AreaParams::default().with_config(config))),
        "serve" => scenario_serve(args, config),
        other => anyhow::bail!("unhandled command `{other}`"),
    }
}

/// The shared `--preset/--file/--p-sub` triple as a [`ConfigSel`].
fn config_sel(args: &Args) -> anyhow::Result<ConfigSel> {
    let mut sel = ConfigSel::preset(args.flag("preset").unwrap_or("paper"));
    if let Some(path) = args.flag("file") {
        let text = std::fs::read_to_string(path)?;
        let pairs = sal_pim::config::parse::parse_pairs(&text)?;
        // Validate against the preset NOW, so a bad override reports the
        // file's real line number (ConfigSel::resolve renumbers its
        // overrides by index).
        sal_pim::config::parse::apply_overrides(
            ConfigSel::preset(&sel.preset).resolve()?,
            &pairs,
        )?;
        for (_, key, value) in pairs {
            sel = sel.with_override(&key, &value);
        }
    }
    if args.flag("p-sub").is_some() {
        sel = sel.with_p_sub(args.get("p-sub", 0usize)?);
    }
    Ok(sel)
}

/// Write the lifecycle event stream as Chrome `trace_event` JSON
/// (loadable in `chrome://tracing` or Perfetto).
fn write_trace(path: &str, events: &[TraceEvent]) -> anyhow::Result<()> {
    std::fs::write(path, chrome_trace_json(events))?;
    eprintln!("wrote {path}");
    Ok(())
}

/// Render an outcome per the `--json` / `--out FILE` flags.
fn emit(args: &Args, outcome: &Outcome) -> anyhow::Result<()> {
    if args.switch("json") {
        println!("{}", sink::to_json(outcome));
    } else {
        print!("{}", sink::render_text(outcome));
    }
    if let Some(path) = args.flag("out") {
        let text = if path.ends_with(".json") {
            let mut s = sink::to_json(outcome);
            s.push('\n');
            s
        } else if path.ends_with(".csv") {
            sink::to_csv(outcome)
        } else {
            sink::render_text(outcome)
        };
        std::fs::write(path, text)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn scenario_serve(args: &Args, config: ConfigSel) -> anyhow::Result<Scenario> {
    let policy_flag = args.flag("policy").unwrap_or("fcfs");
    let policy = parse_policy(policy_flag).ok_or_else(|| {
        anyhow::anyhow!("unknown policy `{policy_flag}` (fcfs|sjf|spf|priority)")
    })?;
    let route_flag = args.flag("route").unwrap_or("rr");
    let route = parse_route(route_flag)
        .ok_or_else(|| anyhow::anyhow!("unknown route `{route_flag}` (rr|ll|affinity)"))?;
    let engine_flag = args.flag("engine").unwrap_or("seq");
    let engine = EngineKind::parse(engine_flag).ok_or_else(|| {
        anyhow::anyhow!("unknown engine `{engine_flag}` (seq|batch|cluster|disagg)")
    })?;
    let backend_flag = args.flag("backend").unwrap_or("salpim");
    let backend =
        BackendKind::parse(backend_flag).map_err(|e| anyhow::anyhow!("bad --backend: {e}"))?;
    let core_flag = args.flag("engine-core").unwrap_or("event");
    let engine_core = EngineCore::parse(core_flag)
        .ok_or_else(|| anyhow::anyhow!("unknown engine-core `{core_flag}` (event|legacy)"))?;
    // Bare `--prefill-chunk` means the 32-token default.
    let prefill_chunk = if args.switch("prefill-chunk") {
        Some(args.get("prefill-chunk", 32usize)?)
    } else {
        None
    };
    let kv_flag = args.flag("kv-policy").unwrap_or("whole");
    let kv_policy = KvPolicy::parse(kv_flag)
        .ok_or_else(|| anyhow::anyhow!("unknown kv-policy `{kv_flag}` (whole|paged)"))?;
    let evict_flag = args.flag("evict").unwrap_or("lru");
    let evict = EvictPolicy::parse(evict_flag)
        .ok_or_else(|| anyhow::anyhow!("unknown evict policy `{evict_flag}` (lru|swap|none)"))?;
    let fabric_flag = args.flag("fabric").unwrap_or("pcie");
    let fabric = FabricKind::parse(fabric_flag)
        .ok_or_else(|| anyhow::anyhow!("unknown fabric `{fabric_flag}` (pcie|nvlink|ideal)"))?;
    let prefill_pool = match args.flag("prefill-pool") {
        Some(_) => Some(args.get("prefill-pool", 0usize)?),
        None => None,
    };
    let decode_pool = match args.flag("decode-pool") {
        Some(_) => Some(args.get("decode-pool", 0usize)?),
        None => None,
    };
    let kv_block = match args.flag("kv-block") {
        Some(_) => Some(args.get("kv-block", 0usize)?),
        None => None,
    };
    let kv_units = match args.flag("kv-units") {
        Some(_) => Some(args.get("kv-units", 0usize)?),
        None => None,
    };
    let rate = match args.flag("rate") {
        Some(_) => Some(args.get("rate", 0.0f64)?),
        None => None,
    };
    let burst = match args.flag("burst") {
        Some(_) => Some(args.get("burst", 4usize)?),
        None => None,
    };
    let prefix_flag = args.flag("prefix-cache").unwrap_or("session");
    let prefix_cache = PrefixCacheMode::parse(prefix_flag).ok_or_else(|| {
        anyhow::anyhow!("unknown prefix-cache mode `{prefix_flag}` (session|radix)")
    })?;
    // `--workload SPEC` supersedes the deprecated `--at-once/--rate/
    // --burst/--sessions` aliases (which desugar to the same specs).
    let workload = match args.flag("workload") {
        Some(s) => Some(
            WorkloadSpec::parse(s).map_err(|e| anyhow::anyhow!("bad --workload spec: {e}"))?,
        ),
        None => None,
    };
    // `--schedule SPEC` supersedes the `--backend` alias (which desugars
    // to `static:<backend>` inside the runner).
    let schedule = match args.flag("schedule") {
        Some(s) => {
            Some(SchedSpec::parse(s).map_err(|e| anyhow::anyhow!("bad --schedule spec: {e}"))?)
        }
        None => None,
    };

    let mut params = ServeParams::default()
        .with_config(config)
        .with_engine(engine)
        .with_backend(backend)
        .with_policy(policy)
        .with_route(route)
        .with_cluster(args.get("devices", 4usize)?, args.get("batch", 8usize)?)
        .with_prefill_chunk(prefill_chunk)
        .with_kv_policy(kv_policy)
        .with_evict(evict)
        .with_kv_block(kv_block)
        .with_kv_units(kv_units)
        .with_fabric(fabric)
        .with_pools(prefill_pool, decode_pool)
        .with_at_once(args.switch("at-once"))
        .with_rate(rate, burst)
        .with_offload(args.switch("offload"))
        .with_engine_core(engine_core)
        .with_prefix_cache(prefix_cache);
    if let Some(w) = workload {
        params = params.with_workload_spec(w);
    }
    if let Some(s) = schedule {
        params = params.with_schedule(s);
    }
    params.n_sessions = args.get("sessions", 8usize)?;
    params.seed = args.get("seed", 42u64)?;
    params.requests = if args.flag("requests").is_some() {
        args.get("requests", 16usize)?
    } else if args.switch("sweep") {
        // Default a sweep to a load big enough to saturate the cluster.
        64
    } else {
        16
    };
    if args.switch("sweep") {
        params = params.with_sweep(vec![50.0, 200.0, 1000.0]);
    }
    Ok(Scenario::Serve(params))
}

/// `sal-pim config` — not an experiment, but it emits an [`Outcome`] too
/// so `--json` / `--out` work uniformly.
fn cmd_config(args: &Args) -> anyhow::Result<()> {
    let sel = config_sel(args)?;
    let cfg = sel.resolve()?;
    let mut out = Outcome::new(
        &format!("config — preset={} P_Sub={}", sel.preset, cfg.parallelism.p_sub),
        Provenance {
            scenario: "config".to_string(),
            preset: sel.preset.clone(),
            p_sub: cfg.parallelism.p_sub,
            backend: None,
            seed: None,
            params: sel
                .overrides
                .iter()
                .map(|(k, v)| (format!("cfg.{k}"), v.clone()))
                .collect(),
            truncated: false,
        },
    );
    out.metric("model", cfg.model.name.as_str(), None);
    out.metric(
        "peak_internal_bandwidth",
        cfg.peak_internal_bandwidth(),
        Some("B/s"),
    );
    out.metric(
        "peak_external_bandwidth",
        cfg.peak_external_bandwidth(),
        Some("B/s"),
    );
    out.note(&format!(
        "peak internal {} | peak external {}",
        fmt_bw(cfg.peak_internal_bandwidth()),
        fmt_bw(cfg.peak_external_bandwidth())
    ));
    if !args.switch("json") {
        println!("{cfg:#?}");
    }
    emit(args, &out)
}

/// `sal-pim compare BASELINE NEW [--tolerance PCT]` — diff two BENCH
/// files metric-by-metric; exits nonzero when a latency/throughput
/// metric regresses beyond the tolerance (the CI bench-diff gate).
fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let (Some(a_path), Some(b_path)) = (args.positional(0), args.positional(1)) else {
        anyhow::bail!("compare needs two BENCH files: sal-pim compare BASELINE NEW");
    };
    let tolerance = args.get("tolerance", 10.0f64)?;
    anyhow::ensure!(
        tolerance >= 0.0,
        "tolerance must be a non-negative percentage, got {tolerance}"
    );
    let a = compare::parse_bench(&std::fs::read_to_string(a_path)?)
        .map_err(|e| anyhow::anyhow!("{a_path}: {e}"))?;
    let b = compare::parse_bench(&std::fs::read_to_string(b_path)?)
        .map_err(|e| anyhow::anyhow!("{b_path}: {e}"))?;
    let report = compare::compare(&a, &b, tolerance);
    let outcome = compare::report_outcome(&report, a_path, b_path);
    emit(args, &outcome)?;
    if report.regressions > 0 {
        anyhow::bail!(
            "{} metric(s) regressed beyond {tolerance}% (baseline {a_path})",
            report.regressions
        );
    }
    if !report.missing.is_empty() && !args.switch("allow-missing") {
        anyhow::bail!(
            "{} baseline metric(s) missing from {b_path} — a metric the gate was \
             watching is no longer reported (pass --allow-missing to tolerate)",
            report.missing.len()
        );
    }
    Ok(())
}

/// `sal-pim run --scenario FILE` — execute a suite, write BENCH files.
fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let Some(path) = args.flag("scenario") else {
        anyhow::bail!("run needs --scenario FILE (see scenarios/smoke.toml)");
    };
    let text = std::fs::read_to_string(path)?;
    let scenarios = parse_suite(&text)?;
    anyhow::ensure!(!scenarios.is_empty(), "suite `{path}` declares no scenarios");
    let runner = Runner::new();
    let trace_path = args.flag("trace");
    anyhow::ensure!(
        trace_path.is_none() || scenarios.iter().any(Runner::traceable),
        "--trace given but `{path}` has no traceable serve scenario \
         (engine batch|cluster, no sweep)"
    );
    let mut traced = false;
    let mut profiles: Vec<PhaseProfile> = Vec::new();
    let mut outcomes: Vec<(String, Outcome)> = Vec::new();
    for scenario in &scenarios {
        // The first traceable scenario wins the --trace file.
        let want_trace = trace_path.is_some() && !traced && Runner::traceable(scenario);
        let (outcome, aux) = runner.run_with(scenario, want_trace)?;
        if want_trace {
            write_trace(trace_path.unwrap_or_default(), &aux.events)?;
            traced = true;
        }
        if let Some(p) = aux.profile {
            profiles.push(p);
        }
        if args.switch("json") {
            println!("{}", sink::to_json(&outcome));
        } else {
            print!("{}", sink::render_text(&outcome));
            println!();
        }
        outcomes.push((scenario.bench_tag().to_string(), outcome));
    }
    // The simulator's own speed, as one more BENCH outcome
    // (`BENCH_simperf.json`) for the bench-diff gate.
    if !profiles.is_empty() {
        let simperf = Runner::simperf_outcome(&profiles);
        if args.switch("json") {
            println!("{}", sink::to_json(&simperf));
        } else {
            print!("{}", sink::render_text(&simperf));
            println!();
        }
        outcomes.push(("simperf".to_string(), simperf));
    }
    let out_dir = args.flag("out-dir").unwrap_or(".");
    let tagged: Vec<(&str, &Outcome)> = outcomes
        .iter()
        .map(|(tag, o)| (tag.as_str(), o))
        .collect();
    let paths = sink::write_bench_files(Path::new(out_dir), &tagged)?;
    for p in &paths {
        eprintln!("wrote {}", p.display());
    }
    if let Some(path) = args.flag("out") {
        // The whole suite as one JSON array.
        let body: Vec<String> = outcomes.iter().map(|(_, o)| sink::to_json(o)).collect();
        std::fs::write(path, format!("[\n{}\n]\n", body.join(",\n")))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}
