//! GPT-2 model layer: operator IR, graph construction, fixed-point
//! arithmetic, synthetic weights and the functional (value-computing)
//! executors.

pub mod fixedpoint;
pub mod functional;
pub mod gpt2;
pub mod ops;
pub mod weights;

pub use functional::{FloatGpt, FunctionalGpt};
pub use ops::GptOp;
