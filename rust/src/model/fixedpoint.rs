//! 16-bit fixed-point arithmetic (§4.1).
//!
//! SAL-PIM computes in 16-bit fixed point with 32-bit accumulation
//! registers; results are shift-truncated back to 16 bits on writeback
//! ("the results are shifted and truncated by fraction bit using
//! shifters"). This module is the single source of truth for that
//! arithmetic — the functional simulator, the LUT generator and the
//! Pallas kernels (via the same Q-format constants exported to
//! `python/compile/kernels`) all use it, so L1 and L3 agree bit-exactly.

/// A Q-format descriptor: `frac_bits` fractional bits in an i16.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QFormat {
    pub frac_bits: u32,
}

/// The default activation/weight format used throughout: Q8.8
/// (range ±128, resolution 1/256) — enough for layer activations after
/// layerNorm and for the interpolation tables' slopes/intercepts.
pub const Q8_8: QFormat = QFormat { frac_bits: 8 };

/// Wider-range format used for logits / pre-softmax scores (Q12.4).
pub const Q12_4: QFormat = QFormat { frac_bits: 4 };

/// High-resolution unit-interval format for softmax exponentials (Q2.13).
pub const Q2_13: QFormat = QFormat { frac_bits: 13 };

/// Unit-interval format for softmax reciprocals (Q0.15): 1/Σexp ∈ (0, 1].
pub const Q0_15: QFormat = QFormat { frac_bits: 15 };

impl QFormat {
    /// Scale factor 2^frac_bits.
    pub fn scale(&self) -> f64 {
        (1i64 << self.frac_bits) as f64
    }

    /// Smallest representable step.
    pub fn epsilon(&self) -> f64 {
        1.0 / self.scale()
    }

    /// Largest representable value.
    pub fn max_value(&self) -> f64 {
        i16::MAX as f64 / self.scale()
    }

    /// Quantize an f64 to the raw i16 representation (round-to-nearest,
    /// saturating — the hardware's clamp on writeback).
    pub fn quantize(&self, x: f64) -> i16 {
        let v = (x * self.scale()).round();
        v.clamp(i16::MIN as f64, i16::MAX as f64) as i16
    }

    /// Dequantize a raw i16 back to f64.
    pub fn dequantize(&self, raw: i16) -> f64 {
        raw as f64 / self.scale()
    }

    /// Multiply two raw values into a raw 32-bit product with
    /// 2×frac_bits fractional bits (what the MAC array produces).
    pub fn mul_raw(&self, a: i16, b: i16) -> i32 {
        a as i32 * b as i32
    }

    /// Shift-truncate a 32-bit accumulator (2×frac_bits) back to a 16-bit
    /// value in this format — the S-ALU writeback shifter. Arithmetic
    /// right shift (truncation toward −∞, as a hardware shifter does),
    /// then saturation.
    pub fn writeback(&self, acc: i32) -> i16 {
        let shifted = acc >> self.frac_bits;
        shifted.clamp(i16::MIN as i32, i16::MAX as i32) as i16
    }

    /// Fixed-point multiply with writeback: `(a*b) >> frac`, saturated.
    pub fn mul(&self, a: i16, b: i16) -> i16 {
        self.writeback(self.mul_raw(a, b))
    }

    /// Saturating add in the 16-bit domain (element-wise S-ALU add).
    pub fn add(&self, a: i16, b: i16) -> i16 {
        (a as i32 + b as i32).clamp(i16::MIN as i32, i16::MAX as i32) as i16
    }

    /// Dot product of raw slices into a 32-bit accumulator (no
    /// intermediate truncation — the S-ALU accumulates at 32 bits).
    /// Saturates the accumulator like the register file would wrap;
    /// we saturate because GPT-2 activations never approach ±2^31 in
    /// Q8.8×Q8.8 with d ≤ 4096 terms.
    pub fn dot_raw(&self, a: &[i16], b: &[i16]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc: i64 = 0;
        for (&x, &w) in a.iter().zip(b.iter()) {
            acc += x as i64 * w as i64;
        }
        acc.clamp(i32::MIN as i64, i32::MAX as i64) as i32
    }

    /// Full fixed-point GEMV row: dot + writeback (+ optional bias raw).
    pub fn gemv_row(&self, x: &[i16], w_row: &[i16], bias: i16) -> i16 {
        let acc = self.dot_raw(x, w_row);
        self.add(self.writeback(acc), bias)
    }

    /// Quantize a float slice.
    pub fn quantize_vec(&self, xs: &[f64]) -> Vec<i16> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Dequantize a raw slice.
    pub fn dequantize_vec(&self, raw: &[i16]) -> Vec<f64> {
        raw.iter().map(|&r| self.dequantize(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn quantize_roundtrip_small_error() {
        let q = Q8_8;
        for x in [-3.5, -0.004, 0.0, 0.2, 1.0, 100.25] {
            let err = (q.dequantize(q.quantize(x)) - x).abs();
            assert!(err <= q.epsilon() / 2.0 + 1e-12, "x={x} err={err}");
        }
    }

    #[test]
    fn quantize_saturates() {
        let q = Q8_8;
        assert_eq!(q.quantize(1e9), i16::MAX);
        assert_eq!(q.quantize(-1e9), i16::MIN);
    }

    #[test]
    fn mul_matches_float() {
        let q = Q8_8;
        let a = q.quantize(1.5);
        let b = q.quantize(-2.25);
        let p = q.dequantize(q.mul(a, b));
        assert!((p - (-3.375)).abs() < 0.01, "got {p}");
    }

    #[test]
    fn writeback_truncates_toward_neg_inf() {
        let q = Q8_8;
        // -1 raw (tiny negative) >> 8 = -1, not 0: hardware shifters
        // truncate toward −∞.
        assert_eq!(q.writeback(-1), -1);
        assert_eq!(q.writeback(255), 0);
        assert_eq!(q.writeback(256), 1);
    }

    #[test]
    fn writeback_saturates() {
        let q = Q8_8;
        assert_eq!(q.writeback(i32::MAX), i16::MAX);
        assert_eq!(q.writeback(i32::MIN), i16::MIN);
    }

    #[test]
    fn dot_matches_float_within_quantization() {
        let q = Q8_8;
        forall(200, |g| {
            let n = g.usize_in(1, 64);
            let xs: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0, 2.0)).collect();
            let ws: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0, 2.0)).collect();
            let xq = q.quantize_vec(&xs);
            let wq = q.quantize_vec(&ws);
            let fx = q.dequantize(q.writeback(q.dot_raw(&xq, &wq)));
            let fl: f64 = xs.iter().zip(&ws).map(|(a, b)| a * b).sum();
            // Error bound: n products each with ~eps relative error + final
            // truncation.
            let bound = (n as f64 + 2.0) * 2.0 * 2.0 * q.epsilon();
            assert!((fx - fl).abs() <= bound, "n={n} fx={fx} fl={fl}");
        });
    }

    #[test]
    fn add_saturates() {
        let q = Q8_8;
        assert_eq!(q.add(i16::MAX, 1), i16::MAX);
        assert_eq!(q.add(i16::MIN, -1), i16::MIN);
        assert_eq!(q.add(100, -30), 70);
    }

    #[test]
    fn gemv_row_includes_bias() {
        let q = Q8_8;
        let x = q.quantize_vec(&[1.0, 2.0]);
        let w = q.quantize_vec(&[3.0, 4.0]);
        let b = q.quantize(0.5);
        let y = q.dequantize(q.gemv_row(&x, &w, b));
        assert!((y - 11.5).abs() < 0.05, "got {y}");
    }

    #[test]
    fn formats_differ() {
        assert_eq!(Q8_8.scale(), 256.0);
        assert_eq!(Q12_4.scale(), 16.0);
        assert!(Q12_4.max_value() > Q8_8.max_value());
    }
}
