//! The GPT operator IR the mapper consumes.
//!
//! One [`GptOp`] is a logical model operator (§2.1's decomposition into
//! matrix-vector, multi-head and non-linear computations); the mapper
//! lowers each into PIM macro-ops under the §3.2 data-mapping schemes.

use crate::stats::Phase;

/// A logical GPT operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GptOp {
    /// Token + positional embedding lookup/add (decode: one token).
    Embed { d: usize },
    /// Layer normalization over a d-vector (mean, σ, rsqrt, affine).
    LayerNorm { d: usize },
    /// y[rows] = W[rows × cols] · x[cols] + b — the GEMV workhorse.
    Gemv {
        rows: usize,
        cols: usize,
        phase: Phase,
    },
    /// Batched GEMV (summarization stage): `batch ≤ 16` token vectors
    /// share one weight stream via the element-wise feeding method
    /// (weights read once per batch, MAC-rate bound).
    Gemm {
        rows: usize,
        cols: usize,
        batch: usize,
        phase: Phase,
    },
    /// Append this token's K,V vectors to the per-bank concatenated
    /// KV store (§3.2's sequential bank mapping).
    KvAppend { d: usize },
    /// scores[kv_len] = Q · Kᵀ per head (Fig. 6(d) direction).
    QkMultiHead {
        heads: usize,
        d_head: usize,
        kv_len: usize,
    },
    /// Softmax over per-head score vectors: max-subtract, LUT exp,
    /// reduce-sum, LUT reciprocal, scale.
    Softmax { heads: usize, kv_len: usize },
    /// out[d_head] = Σ_t s[t] · V[t] per head (Fig. 6(c) direction).
    SvMultiHead {
        heads: usize,
        d_head: usize,
        kv_len: usize,
    },
    /// GELU activation over a d-vector via LUT interpolation.
    Gelu { d: usize },
    /// Residual addition of two d-vectors.
    Residual { d: usize },
    /// Greedy sampling: argmax over the logit vector.
    Sample { vocab: usize },
}

impl GptOp {
    /// Phase attribution for breakdown reporting.
    pub fn phase(&self) -> Phase {
        match self {
            GptOp::Embed { .. } => Phase::Embedding,
            GptOp::LayerNorm { .. } | GptOp::Softmax { .. } | GptOp::Gelu { .. } => {
                Phase::NonLinear
            }
            GptOp::Gemv { phase, .. } | GptOp::Gemm { phase, .. } => *phase,
            GptOp::QkMultiHead { .. } | GptOp::SvMultiHead { .. } | GptOp::KvAppend { .. } => {
                Phase::Mha
            }
            GptOp::Residual { .. } => Phase::Residual,
            GptOp::Sample { .. } => Phase::LmHead,
        }
    }

    /// Weight bytes this operator streams (16-bit parameters), for
    /// traffic invariants.
    pub fn weight_bytes(&self) -> usize {
        match *self {
            GptOp::Gemv { rows, cols, .. } => (rows * cols + rows) * 2,
            GptOp::Gemm { rows, cols, .. } => (rows * cols + rows) * 2,
            GptOp::QkMultiHead {
                heads,
                d_head,
                kv_len,
            } => heads * d_head * kv_len * 2,
            GptOp::SvMultiHead {
                heads,
                d_head,
                kv_len,
            } => heads * d_head * kv_len * 2,
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_assigned() {
        assert_eq!(GptOp::Gelu { d: 4096 }.phase(), Phase::NonLinear);
        assert_eq!(
            GptOp::Gemv {
                rows: 1,
                cols: 1,
                phase: Phase::Ffn
            }
            .phase(),
            Phase::Ffn
        );
        assert_eq!(
            GptOp::QkMultiHead {
                heads: 16,
                d_head: 64,
                kv_len: 10
            }
            .phase(),
            Phase::Mha
        );
    }

    #[test]
    fn weight_bytes_counts_bias() {
        let op = GptOp::Gemv {
            rows: 4,
            cols: 8,
            phase: Phase::Ffn,
        };
        assert_eq!(op.weight_bytes(), (32 + 4) * 2);
        assert_eq!(GptOp::Residual { d: 100 }.weight_bytes(), 0);
    }
}
