//! GPT-2 operator-graph construction (Fig. 2 structure).
//!
//! Builds the exact operator sequence SAL-PIM executes end-to-end: the
//! embedding layer, 24 identical decoder layers (layerNorm → MHA →
//! residual → layerNorm → FFN → residual) and the LM head.

use super::ops::GptOp;
use crate::config::ModelConfig;
use crate::stats::Phase;

/// Operator sequence of one decode iteration (generation stage) with
/// `kv_len` tokens already in the KV store (including this one).
pub fn decode_ops(m: &ModelConfig, kv_len: usize) -> Vec<GptOp> {
    assert!(kv_len >= 1, "kv_len includes the current token");
    let d = m.d_model;
    let mut ops = vec![GptOp::Embed { d }];
    for _ in 0..m.n_layers {
        ops.extend_from_slice(&layer_ops(m, kv_len, 1));
    }
    // Final layerNorm + LM head + sampling.
    ops.push(GptOp::LayerNorm { d });
    ops.push(GptOp::Gemv {
        rows: m.vocab,
        cols: d,
        phase: Phase::LmHead,
    });
    ops.push(GptOp::Sample { vocab: m.vocab });
    ops
}

/// Operator sequence of the summarization (prefill) stage over `n_in`
/// input tokens. Tokens are processed in batches of up to 16 (the
/// element-wise feeding width); attention inside a batch sees the KV
/// store grown to the batch's end position (a conservative bound for the
/// causal mask).
pub fn prefill_ops(m: &ModelConfig, n_in: usize) -> Vec<GptOp> {
    assert!(n_in >= 1);
    let d = m.d_model;
    let mut ops = Vec::new();
    let mut done = 0;
    while done < n_in {
        let batch = (n_in - done).min(16);
        let kv_end = done + batch;
        ops.push(GptOp::Embed { d });
        for _ in 0..m.n_layers {
            ops.extend_from_slice(&batch_layer_ops(m, kv_end, batch));
        }
        done += batch;
    }
    // The summarization stage emits one token: final LN + LM head once.
    ops.push(GptOp::LayerNorm { d });
    ops.push(GptOp::Gemv {
        rows: m.vocab,
        cols: d,
        phase: Phase::LmHead,
    });
    ops.push(GptOp::Sample { vocab: m.vocab });
    ops
}

/// One decoder layer for a single token (decode path).
fn layer_ops(m: &ModelConfig, kv_len: usize, _batch: usize) -> Vec<GptOp> {
    let d = m.d_model;
    vec![
        GptOp::LayerNorm { d },
        // Q, K, V projections.
        GptOp::Gemv {
            rows: d,
            cols: d,
            phase: Phase::Mha,
        },
        GptOp::Gemv {
            rows: d,
            cols: d,
            phase: Phase::Mha,
        },
        GptOp::Gemv {
            rows: d,
            cols: d,
            phase: Phase::Mha,
        },
        GptOp::KvAppend { d },
        GptOp::QkMultiHead {
            heads: m.n_heads,
            d_head: m.d_head(),
            kv_len,
        },
        GptOp::Softmax {
            heads: m.n_heads,
            kv_len,
        },
        GptOp::SvMultiHead {
            heads: m.n_heads,
            d_head: m.d_head(),
            kv_len,
        },
        // Output projection + residual.
        GptOp::Gemv {
            rows: d,
            cols: d,
            phase: Phase::Mha,
        },
        GptOp::Residual { d },
        GptOp::LayerNorm { d },
        // FFN.
        GptOp::Gemv {
            rows: m.d_ff,
            cols: d,
            phase: Phase::Ffn,
        },
        GptOp::Gelu { d: m.d_ff },
        GptOp::Gemv {
            rows: d,
            cols: m.d_ff,
            phase: Phase::Ffn,
        },
        GptOp::Residual { d },
    ]
}

/// One decoder layer for a `batch`-token prefill step.
fn batch_layer_ops(m: &ModelConfig, kv_end: usize, batch: usize) -> Vec<GptOp> {
    let d = m.d_model;
    let mut ops = vec![GptOp::LayerNorm { d: d * batch }];
    for _ in 0..3 {
        ops.push(GptOp::Gemm {
            rows: d,
            cols: d,
            batch,
            phase: Phase::Mha,
        });
    }
    ops.push(GptOp::KvAppend { d: d * batch });
    // Per-token attention against the causal prefix (bounded by kv_end).
    for _ in 0..batch {
        ops.push(GptOp::QkMultiHead {
            heads: m.n_heads,
            d_head: m.d_head(),
            kv_len: kv_end,
        });
        ops.push(GptOp::Softmax {
            heads: m.n_heads,
            kv_len: kv_end,
        });
        ops.push(GptOp::SvMultiHead {
            heads: m.n_heads,
            d_head: m.d_head(),
            kv_len: kv_end,
        });
    }
    ops.push(GptOp::Gemm {
        rows: d,
        cols: d,
        batch,
        phase: Phase::Mha,
    });
    ops.push(GptOp::Residual { d: d * batch });
    ops.push(GptOp::LayerNorm { d: d * batch });
    ops.push(GptOp::Gemm {
        rows: m.d_ff,
        cols: d,
        batch,
        phase: Phase::Ffn,
    });
    ops.push(GptOp::Gelu { d: m.d_ff * batch });
    ops.push(GptOp::Gemm {
        rows: d,
        cols: m.d_ff,
        batch,
        phase: Phase::Ffn,
    });
    ops.push(GptOp::Residual { d: d * batch });
    ops
}

/// Total weight bytes streamed by a decode iteration — must equal the
/// model's per-token traffic (invariant test).
pub fn decode_weight_bytes(m: &ModelConfig, kv_len: usize) -> usize {
    decode_ops(m, kv_len).iter().map(|o| o.weight_bytes()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn decode_op_counts() {
        let m = ModelConfig::gpt2_medium();
        let ops = decode_ops(&m, 10);
        // 1 embed + 24 × 15 layer ops + LN + LM head + sample.
        assert_eq!(ops.len(), 1 + 24 * 15 + 3);
        assert!(matches!(ops[0], GptOp::Embed { .. }));
        assert!(matches!(ops.last(), Some(GptOp::Sample { .. })));
    }

    #[test]
    fn decode_streams_all_weights() {
        // A decode iteration must stream every decoder weight + LM head:
        // 4d² + 2·d·dff per layer (+biases) + vocab·d.
        let m = ModelConfig::gpt2_medium();
        let bytes = decode_weight_bytes(&m, 1);
        let d = m.d_model;
        let min_expected = 2 * (m.n_layers * (4 * d * d + 2 * d * m.d_ff) + m.vocab * d);
        assert!(bytes >= min_expected, "{bytes} < {min_expected}");
        // Within 2 % (biases + KV reads at kv=1).
        assert!((bytes as f64) < min_expected as f64 * 1.02);
    }

    #[test]
    fn kv_reads_grow_with_context() {
        let m = ModelConfig::gpt2_medium();
        assert!(decode_weight_bytes(&m, 1024) > decode_weight_bytes(&m, 1));
    }

    #[test]
    fn prefill_batches_by_16() {
        let m = ModelConfig::gpt2_medium();
        let ops32 = prefill_ops(&m, 32);
        let embeds = ops32
            .iter()
            .filter(|o| matches!(o, GptOp::Embed { .. }))
            .count();
        assert_eq!(embeds, 2); // two batches of 16

        let ops33 = prefill_ops(&m, 33);
        let embeds33 = ops33
            .iter()
            .filter(|o| matches!(o, GptOp::Embed { .. }))
            .count();
        assert_eq!(embeds33, 3); // 16 + 16 + 1
    }

    #[test]
    fn prefill_reuses_weights_via_gemm() {
        let m = ModelConfig::gpt2_medium();
        let ops = prefill_ops(&m, 32);
        // Prefill must not contain plain decode GEMVs for the layers
        // (only the final LM head GEMV).
        let gemvs = ops
            .iter()
            .filter(|o| matches!(o, GptOp::Gemv { .. }))
            .count();
        assert_eq!(gemvs, 1);
        let gemms = ops
            .iter()
            .filter(|o| matches!(o, GptOp::Gemm { .. }))
            .count();
        assert_eq!(gemms, 2 * 24 * 6); // 2 batches × 24 layers × 6 GEMMs
    }

    #[test]
    fn mini_model_graph_builds() {
        let m = ModelConfig::gpt2_mini();
        let ops = decode_ops(&m, 4);
        assert_eq!(ops.len(), 1 + 2 * 15 + 3);
    }
}
