//! Functional (value-computing) GPT execution.
//!
//! [`FunctionalGpt`] runs decode steps **bit-exactly the way the SAL-PIM
//! hardware would**: 16-bit fixed-point operands, 32-bit S-ALU
//! accumulation, shift-truncate writebacks, LUT-based linear
//! interpolation for every non-linear function, C-ALU tree reductions.
//! [`FloatGpt`] is the f64 reference executing the same graph with exact
//! non-linearities — the in-crate golden model (the cross-language golden
//! model is the AOT-compiled JAX graph via [`crate::runtime`]).

use super::fixedpoint::{QFormat, Q2_13, Q8_8};
use super::weights::GptWeights;
use crate::config::{ModelConfig, SimConfig};
use crate::interp::NonLinFn;
use crate::pim::lut_subarray::LutSubarrays;

/// Fixed-point functional model with KV cache.
pub struct FunctionalGpt {
    pub w: GptWeights,
    pub luts: LutSubarrays,
    /// Per-layer K cache: kv_len × d_model raw values.
    kv_k: Vec<Vec<i16>>,
    kv_v: Vec<Vec<i16>>,
    pub pos: usize,
    q: QFormat,
    m: ModelConfig,
}

impl FunctionalGpt {
    pub fn new(sim: &SimConfig) -> Self {
        let m = sim.model.clone();
        FunctionalGpt {
            w: GptWeights::synthetic(&m, Q8_8),
            luts: LutSubarrays::new(sim),
            kv_k: vec![Vec::new(); m.n_layers],
            kv_v: vec![Vec::new(); m.n_layers],
            pos: 0,
            q: Q8_8,
            m,
        }
    }

    /// Clear the KV cache (new sequence).
    pub fn reset(&mut self) {
        for k in &mut self.kv_k {
            k.clear();
        }
        for v in &mut self.kv_v {
            v.clear();
        }
        self.pos = 0;
    }

    /// Fixed-point GEMV: y = Wx + b with 32-bit accumulation (S-ALU
    /// semantics; rows of W are row-major).
    fn gemv(&self, w: &[i16], b: &[i16], x: &[i16], rows: usize, cols: usize) -> Vec<i16> {
        debug_assert_eq!(w.len(), rows * cols);
        debug_assert_eq!(x.len(), cols);
        (0..rows)
            .map(|r| self.q.gemv_row(x, &w[r * cols..(r + 1) * cols], b[r]))
            .collect()
    }

    /// Fixed-point layerNorm: mean and variance via C-ALU-style integer
    /// reductions, 1/σ via the rsqrt LUT with power-of-4 range reduction.
    fn layernorm(&self, x: &[i16], gamma: &[i16], beta: &[i16]) -> Vec<i16> {
        let d = x.len() as i64;
        let sum: i64 = x.iter().map(|&v| v as i64).sum();
        let mean = (sum / d) as i32; // Q8.8
        let var_q16: i64 = x
            .iter()
            .map(|&v| {
                let c = v as i64 - mean as i64;
                c * c
            })
            .sum::<i64>()
            / d;
        let var_q8 = ((var_q16 >> 8) as i32).max(1); // Q8.8, floor at ε
        let inv_sigma = self.rsqrt_fixed(var_q8); // Q8.8
        x.iter()
            .zip(gamma.iter().zip(beta.iter()))
            .map(|(&v, (&g, &b))| {
                let centered = v as i32 - mean; // Q8.8
                let normed = (centered * inv_sigma as i32) >> 8; // Q8.8
                let scaled = (normed * g as i32) >> 8;
                (scaled + b as i32).clamp(i16::MIN as i32, i16::MAX as i32) as i16
            })
            .collect()
    }

    /// 1/√x for raw Q8.8 `x > 0`: range-reduce by powers of 4 into the
    /// mantissa table [1, 4), then shift the result by 2^−k.
    pub fn rsqrt_fixed(&self, raw_q8: i32) -> i16 {
        assert!(raw_q8 > 0);
        let mut m = raw_q8;
        let mut k: i32 = 0;
        while m >= 1024 {
            m >>= 2;
            k += 1;
        }
        while m < 256 {
            m <<= 2;
            k -= 1;
        }
        let base = self.luts.table(NonLinFn::Rsqrt).eval_raw(m as i16) as i32; // Q8.8
        let shifted = if k >= 0 { base >> k } else { base << (-k).min(14) };
        shifted.clamp(i16::MIN as i32, i16::MAX as i32) as i16
    }

    /// 1/x for a positive 32-bit Q2.13 accumulator (softmax denominator):
    /// range-reduce by powers of 2 into [1, 2), table in Q2.13, return
    /// (mantissa_recip_q213, k) with 1/x = recip · 2^−k.
    pub fn recip_fixed_q213(&self, raw_q213: i64) -> (i16, i32) {
        assert!(raw_q213 > 0);
        let one = 1i64 << 13;
        let mut m = raw_q213;
        let mut k: i32 = 0;
        while m >= 2 * one {
            m >>= 1;
            k += 1;
        }
        while m < one {
            m <<= 1;
            k -= 1;
        }
        // Mantissa in [1,2) Q2.13 → Q8.8 table input.
        let m_q8 = (m >> 5) as i16;
        let recip = self.luts.table(NonLinFn::Recip).eval_raw(m_q8); // Q2.13
        (recip, k)
    }

    /// Softmax over raw Q8.8 scores (the §3.2.1 dataflow: max-subtract →
    /// LUT exp (Q2.13) → reduce-sum → LUT reciprocal → scale). Output in
    /// Q2.13 attention weights.
    fn softmax_q213(&self, scores: &[i16]) -> Vec<i16> {
        let max = *scores.iter().max().unwrap();
        let exp_t = self.luts.table(NonLinFn::Exp);
        let exps: Vec<i16> = scores
            .iter()
            .map(|&s| {
                let shifted = (s as i32 - max as i32).max(i16::MIN as i32) as i16;
                // Edge-section intercept error can dip below zero;
                // exponentials are clamped non-negative (as the kernel
                // and python reference do).
                exp_t.eval_raw(shifted).max(0) // Q2.13
            })
            .collect();
        let sum: i64 = exps.iter().map(|&e| e as i64).sum::<i64>().max(1);
        let (recip, k) = self.recip_fixed_q213(sum);
        exps.iter()
            .map(|&e| {
                let prod = e as i64 * recip as i64; // Q4.26
                let shift = 13 + k.max(0);
                let v = if k >= 0 {
                    prod >> shift
                } else {
                    (prod >> 13) << (-k).min(14)
                };
                v.clamp(0, i16::MAX as i64) as i16
            })
            .collect()
    }

    /// One decode step: embed `token`, run all layers, return (argmax
    /// token, raw logits).
    pub fn decode_step(&mut self, token: usize) -> (usize, Vec<i16>) {
        let d = self.m.d_model;
        let dh = self.m.d_head();
        let heads = self.m.n_heads;
        assert!(token < self.m.vocab);
        assert!(self.pos < self.m.max_seq, "KV capacity exceeded");

        // Embedding + positional.
        let mut x: Vec<i16> = (0..d)
            .map(|i| {
                self.q
                    .add(self.w.wte[token * d + i], self.w.wpe[self.pos * d + i])
            })
            .collect();

        let scale_q213 = Q2_13.quantize(1.0 / (dh as f64).sqrt());
        for l in 0..self.m.n_layers {
            let lw = self.w.layers[l].clone();
            // --- MHA ---
            let h = self.layernorm(&x, &lw.ln1_g, &lw.ln1_b);
            let qv = self.gemv(&lw.wq, &lw.bq, &h, d, d);
            let kv = self.gemv(&lw.wk, &lw.bk, &h, d, d);
            let vv = self.gemv(&lw.wv, &lw.bv, &h, d, d);
            self.kv_k[l].extend_from_slice(&kv);
            self.kv_v[l].extend_from_slice(&vv);
            let kv_len = self.kv_k[l].len() / d;

            let mut attn_out = vec![0i16; d];
            for hd in 0..heads {
                let off = hd * dh;
                // scores[t] = (Q·K_t) / √dh  (Fig. 6(d) direction).
                let scores: Vec<i16> = (0..kv_len)
                    .map(|t| {
                        let krow = &self.kv_k[l][t * d + off..t * d + off + dh];
                        let dot = self.q.dot_raw(&qv[off..off + dh], krow); // Q16.16
                        let scaled = (dot as i64 * scale_q213 as i64) >> (13 + 8);
                        scaled.clamp(i16::MIN as i64, i16::MAX as i64) as i16 // Q8.8
                    })
                    .collect();
                let s = self.softmax_q213(&scores);
                // out = Σ_t s_t · V_t (Fig. 6(c) direction), 32-bit acc.
                for i in 0..dh {
                    let mut acc: i64 = 0;
                    for (t, &st) in s.iter().enumerate() {
                        acc += st as i64 * self.kv_v[l][t * d + off + i] as i64; // Q10.21
                    }
                    attn_out[off + i] =
                        (acc >> 13).clamp(i16::MIN as i64, i16::MAX as i64) as i16;
                }
            }
            let proj = self.gemv(&lw.wo, &lw.bo, &attn_out, d, d);
            for i in 0..d {
                x[i] = self.q.add(x[i], proj[i]);
            }

            // --- FFN ---
            let h = self.layernorm(&x, &lw.ln2_g, &lw.ln2_b);
            let mut ff = self.gemv(&lw.w1, &lw.b1, &h, self.m.d_ff, d);
            let gelu_t = self.luts.table(NonLinFn::Gelu);
            for v in &mut ff {
                *v = gelu_t.eval_raw(*v);
            }
            let ff2 = self.gemv(&lw.w2, &lw.b2, &ff, d, self.m.d_ff);
            for i in 0..d {
                x[i] = self.q.add(x[i], ff2[i]);
            }
        }

        // Final LN + LM head (tied to the embedding table, GPT-2 style).
        let h = self.layernorm(&x, &self.w.lnf_g.clone(), &self.w.lnf_b.clone());
        let logits: Vec<i16> = (0..self.m.vocab)
            .map(|v| {
                let row = &self.w.wte[v * d..(v + 1) * d];
                self.q.writeback(self.q.dot_raw(&h, row))
            })
            .collect();
        let next = argmax(&logits);
        self.pos += 1;
        (next, logits)
    }

    /// Run a whole generation: prefill `prompt`, then decode `n_out`
    /// tokens greedily. Returns the generated token ids.
    pub fn generate(&mut self, prompt: &[usize], n_out: usize) -> Vec<usize> {
        self.reset();
        let mut next = 0;
        for &t in prompt {
            next = self.decode_step(t).0;
        }
        let mut out = Vec::with_capacity(n_out);
        for _ in 0..n_out {
            out.push(next);
            next = self.decode_step(next).0;
        }
        out
    }
}

fn argmax<T: PartialOrd + Copy>(xs: &[T]) -> usize {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

/// f64 reference model: same weights (dequantized), same graph, exact
/// non-linearities.
pub struct FloatGpt {
    pub w: GptWeights,
    kv_k: Vec<Vec<f64>>,
    kv_v: Vec<Vec<f64>>,
    pub pos: usize,
    m: ModelConfig,
}

impl FloatGpt {
    pub fn new(sim: &SimConfig) -> Self {
        let m = sim.model.clone();
        FloatGpt {
            w: GptWeights::synthetic(&m, Q8_8),
            kv_k: vec![Vec::new(); m.n_layers],
            kv_v: vec![Vec::new(); m.n_layers],
            pos: 0,
            m,
        }
    }

    pub fn reset(&mut self) {
        for k in &mut self.kv_k {
            k.clear();
        }
        for v in &mut self.kv_v {
            v.clear();
        }
        self.pos = 0;
    }

    fn deq(&self, raw: &[i16]) -> Vec<f64> {
        self.w.dequant(raw)
    }

    fn gemv(&self, w: &[i16], b: &[i16], x: &[f64], rows: usize, cols: usize) -> Vec<f64> {
        let wf = self.deq(w);
        let bf = self.deq(b);
        (0..rows)
            .map(|r| {
                bf[r]
                    + x.iter()
                        .zip(&wf[r * cols..(r + 1) * cols])
                        .map(|(a, b)| a * b)
                        .sum::<f64>()
            })
            .collect()
    }

    fn layernorm(&self, x: &[f64], gamma: &[i16], beta: &[i16]) -> Vec<f64> {
        let d = x.len() as f64;
        let mean = x.iter().sum::<f64>() / d;
        let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / d;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let g = self.deq(gamma);
        let b = self.deq(beta);
        x.iter()
            .enumerate()
            .map(|(i, &v)| (v - mean) * inv * g[i] + b[i])
            .collect()
    }

    pub fn decode_step(&mut self, token: usize) -> (usize, Vec<f64>) {
        let d = self.m.d_model;
        let dh = self.m.d_head();
        let heads = self.m.n_heads;
        let wte = self.deq(&self.w.wte);
        let wpe = self.deq(&self.w.wpe);
        let mut x: Vec<f64> = (0..d)
            .map(|i| wte[token * d + i] + wpe[self.pos * d + i])
            .collect();

        for l in 0..self.m.n_layers {
            let lw = self.w.layers[l].clone();
            let h = self.layernorm(&x, &lw.ln1_g, &lw.ln1_b);
            let qv = self.gemv(&lw.wq, &lw.bq, &h, d, d);
            let kv = self.gemv(&lw.wk, &lw.bk, &h, d, d);
            let vv = self.gemv(&lw.wv, &lw.bv, &h, d, d);
            self.kv_k[l].extend_from_slice(&kv);
            self.kv_v[l].extend_from_slice(&vv);
            let kv_len = self.kv_k[l].len() / d;

            let mut attn_out = vec![0f64; d];
            for hd in 0..heads {
                let off = hd * dh;
                let scores: Vec<f64> = (0..kv_len)
                    .map(|t| {
                        let krow = &self.kv_k[l][t * d + off..t * d + off + dh];
                        qv[off..off + dh]
                            .iter()
                            .zip(krow)
                            .map(|(a, b)| a * b)
                            .sum::<f64>()
                            / (dh as f64).sqrt()
                    })
                    .collect();
                let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let exps: Vec<f64> = scores.iter().map(|&s| (s - max).exp()).collect();
                let sum: f64 = exps.iter().sum();
                for i in 0..dh {
                    attn_out[off + i] = (0..kv_len)
                        .map(|t| exps[t] / sum * self.kv_v[l][t * d + off + i])
                        .sum();
                }
            }
            let proj = self.gemv(&lw.wo, &lw.bo, &attn_out, d, d);
            for i in 0..d {
                x[i] += proj[i];
            }

            let h = self.layernorm(&x, &lw.ln2_g, &lw.ln2_b);
            let mut ff = self.gemv(&lw.w1, &lw.b1, &h, self.m.d_ff, d);
            for v in &mut ff {
                *v = NonLinFn::Gelu.eval_exact(*v);
            }
            let ff2 = self.gemv(&lw.w2, &lw.b2, &ff, d, self.m.d_ff);
            for i in 0..d {
                x[i] += ff2[i];
            }
        }

        let lnf_g = self.w.lnf_g.clone();
        let lnf_b = self.w.lnf_b.clone();
        let h = self.layernorm(&x, &lnf_g, &lnf_b);
        let wte = self.deq(&self.w.wte);
        let logits: Vec<f64> = (0..self.m.vocab)
            .map(|v| {
                h.iter()
                    .zip(&wte[v * d..(v + 1) * d])
                    .map(|(a, b)| a * b)
                    .sum()
            })
            .collect();
        let next = argmax(&logits);
        self.pos += 1;
        (next, logits)
    }

    pub fn generate(&mut self, prompt: &[usize], n_out: usize) -> Vec<usize> {
        self.reset();
        let mut next = 0;
        for &t in prompt {
            next = self.decode_step(t).0;
        }
        let mut out = Vec::with_capacity(n_out);
        for _ in 0..n_out {
            out.push(next);
            next = self.decode_step(next).0;
        }
        out
    }
}

/// Top-1 agreement between the fixed-point and float models over a set of
/// prompts — the §4.1 "accuracy only dropped about 2.8 %" proxy.
pub fn top1_agreement(sim: &SimConfig, prompts: &[Vec<usize>]) -> f64 {
    let mut fx = FunctionalGpt::new(sim);
    let mut fl = FloatGpt::new(sim);
    let mut agree = 0usize;
    let mut total = 0usize;
    for p in prompts {
        fx.reset();
        fl.reset();
        for &t in p {
            let a = fx.decode_step(t).0;
            let b = fl.decode_step(t).0;
            agree += (a == b) as usize;
            total += 1;
        }
    }
    agree as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini() -> SimConfig {
        SimConfig::mini()
    }

    #[test]
    fn decode_step_produces_valid_token() {
        let cfg = mini();
        let mut g = FunctionalGpt::new(&cfg);
        let (t, logits) = g.decode_step(5);
        assert!(t < cfg.model.vocab);
        assert_eq!(logits.len(), cfg.model.vocab);
        assert_eq!(g.pos, 1);
    }

    #[test]
    fn fixed_point_tracks_float_logits() {
        let cfg = mini();
        let mut fx = FunctionalGpt::new(&cfg);
        let mut fl = FloatGpt::new(&cfg);
        let (_, lq) = fx.decode_step(7);
        let (_, lf) = fl.decode_step(7);
        // Compare normalized logit vectors: correlation must be high.
        let lqf: Vec<f64> = lq.iter().map(|&v| Q8_8.dequantize(v)).collect();
        let corr = correlation(&lqf, &lf);
        assert!(corr > 0.95, "corr {corr}");
    }

    #[test]
    fn kv_cache_grows_and_resets() {
        let cfg = mini();
        let mut g = FunctionalGpt::new(&cfg);
        g.decode_step(1);
        g.decode_step(2);
        assert_eq!(g.kv_k[0].len(), 2 * cfg.model.d_model);
        g.reset();
        assert_eq!(g.kv_k[0].len(), 0);
        assert_eq!(g.pos, 0);
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = mini();
        let mut g = FunctionalGpt::new(&cfg);
        let a = g.generate(&[1, 2, 3], 8);
        let b = g.generate(&[1, 2, 3], 8);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
    }

    #[test]
    fn agreement_with_float_model_is_high() {
        // §4.1: ~2.8 % accuracy drop at 16-bit fixed point. Our proxy:
        // top-1 next-token agreement between fixed and float models.
        let cfg = mini();
        let prompts: Vec<Vec<usize>> = (0..4)
            .map(|i| (0..6).map(|j| (i * 37 + j * 11) % 256).collect())
            .collect();
        let agreement = top1_agreement(&cfg, &prompts);
        assert!(agreement > 0.85, "agreement {agreement}");
    }

    #[test]
    fn rsqrt_fixed_tracks_float() {
        let cfg = mini();
        let g = FunctionalGpt::new(&cfg);
        for x in [0.1f64, 0.5, 1.0, 3.0, 9.0, 50.0] {
            let raw = (x * 256.0) as i32;
            let got = Q8_8.dequantize(g.rsqrt_fixed(raw));
            let want = 1.0 / x.sqrt();
            assert!(
                (got - want).abs() / want < 0.06,
                "rsqrt({x}) got {got} want {want}"
            );
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let cfg = mini();
        let g = FunctionalGpt::new(&cfg);
        let scores: Vec<i16> = [0.5, 1.0, -0.25, 2.0, 0.0]
            .iter()
            .map(|&x: &f64| Q8_8.quantize(x))
            .collect();
        let s = g.softmax_q213(&scores);
        let total: f64 = s.iter().map(|&v| Q2_13.dequantize(v)).sum();
        assert!((total - 1.0).abs() < 0.05, "sum {total}");
        // Largest score gets the largest weight.
        assert_eq!(argmax(&s), 3);
    }

    fn correlation(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().sum::<f64>() / n;
        let mb = b.iter().sum::<f64>() / n;
        let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
        let va: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum();
        let vb: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum();
        cov / (va.sqrt() * vb.sqrt())
    }
}
