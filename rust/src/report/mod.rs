//! Table/figure formatting for the bench harness and CLI.
//!
//! Every paper artifact is regenerated as a plain-text table whose rows
//! mirror what the paper reports; these helpers keep the formatting
//! uniform across benches.

use std::fmt::Write as _;

/// A simple fixed-width text table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Column widths sized to content.
    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned text table (also valid Markdown).
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, " {:<width$} |", c, width = w[i]);
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.headers, &w));
        let mut sep = String::from("|");
        for width in &w {
            let _ = write!(sep, "{}|", "-".repeat(width + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &w));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format seconds with an appropriate unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Format bytes/sec as GB/s / TB/s.
pub fn fmt_bw(bps: f64) -> String {
    if bps >= 1e12 {
        format!("{:.2} TB/s", bps / 1e12)
    } else {
        format!("{:.1} GB/s", bps / 1e9)
    }
}

/// Format a ratio as `N.NN×`.
pub fn fmt_x(r: f64) -> String {
    format!("{r:.2}×")
}

/// Format a fraction as a percentage (`0.073` → `7.3%`).
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned_markdown() {
        let mut t = Table::new("Fig. X", &["in", "out", "speedup"]);
        t.row(&["32".into(), "128".into(), "4.72×".into()]);
        t.row(&["128".into(), "1".into(), "0.80×".into()]);
        let r = t.render();
        assert!(r.contains("## Fig. X"));
        assert!(r.lines().count() == 5);
        assert!(r.contains("| 32 "));
        assert!(r.contains("|----"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(1.5), "1.500 s");
        assert_eq!(fmt_time(3.3e-5), "33.0 µs");
        assert_eq!(fmt_bw(8.19e12), "8.19 TB/s");
        assert_eq!(fmt_bw(672e9), "672.0 GB/s");
        assert_eq!(fmt_x(4.7234), "4.72×");
        assert_eq!(fmt_pct(0.0731), "7.3%");
        assert_eq!(fmt_pct(1.0), "100.0%");
    }
}
