//! Per-request span timelines derived from the event stream.
//!
//! A completed request's lifetime decomposes into `queue` (arrival →
//! admission), `prefill` (admission → last prefill chunk), and an
//! alternation of `decode` / `preempted` segments (a `preempted` span
//! covers both the readmission-queue wait and the recompute charge,
//! because decode only resumes once the recompute has been paid). The
//! spans tile `[arrival, finish]` *exactly* — each span starts at the
//! previous span's end by construction — which
//! [`RequestSpans::tiles_exactly`] checks with strict float equality.

use super::event::{TraceEvent, TraceEventKind};
use std::collections::BTreeMap;

/// Which lifecycle phase a span covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Waiting for admission (arrival → KV grant).
    Queue,
    /// Summarization (admission → last prefill chunk; zero-width when
    /// the whole prompt was reclaimed from session residency).
    Prefill,
    /// Producing tokens in the decode batch.
    Decode,
    /// Preempted: KV dropped, waiting for readmission + recompute.
    Preempted,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Queue => "queue",
            SpanKind::Prefill => "prefill",
            SpanKind::Decode => "decode",
            SpanKind::Preempted => "preempted",
        }
    }
}

/// One phase of a request's lifetime, `[start_s, end_s]` in simulated
/// seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    pub start_s: f64,
    pub end_s: f64,
}

impl Span {
    pub fn width_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// The derived timeline of one completed request.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestSpans {
    pub id: u64,
    /// Device that served the request (the admission's device stamp).
    pub device: usize,
    pub arrival_s: f64,
    pub finish_s: f64,
    /// Queue, prefill, then alternating decode/preempted segments.
    pub spans: Vec<Span>,
}

impl RequestSpans {
    /// The tiling invariant: the first span starts at the arrival, the
    /// last ends at the finish, no span has negative width, and every
    /// span starts exactly (bit-for-bit) where the previous one ends.
    pub fn tiles_exactly(&self) -> bool {
        let (Some(first), Some(last)) = (self.spans.first(), self.spans.last()) else {
            return false;
        };
        first.start_s == self.arrival_s
            && last.end_s == self.finish_s
            && self.spans.iter().all(|s| s.end_s >= s.start_s)
            && self
                .spans
                .windows(2)
                .all(|w| w[0].end_s == w[1].start_s)
    }

    /// Total width of all spans of one kind.
    pub fn width_of(&self, kind: SpanKind) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.kind == kind)
            .map(Span::width_s)
            .sum()
    }
}

#[derive(Default)]
struct PerRequest {
    arrival: Option<f64>,
    admit: Option<f64>,
    device: usize,
    prefill_end: Option<f64>,
    /// `(true, t)` = preempted at `t`; `(false, t)` = readmitted at `t`.
    marks: Vec<(bool, f64)>,
    finish: Option<f64>,
}

/// Derive span timelines for every request that completed inside the
/// event stream. Requests that were rejected, or still in flight when a
/// wall-clock budget truncated the run, have no `Complete` event and
/// are skipped.
pub fn derive_spans(events: &[TraceEvent]) -> Vec<RequestSpans> {
    let mut per: BTreeMap<u64, PerRequest> = BTreeMap::new();
    for e in events {
        match e.kind {
            TraceEventKind::Arrival { id, .. } => {
                let r = per.entry(id).or_default();
                r.arrival = Some(e.t_s);
                r.device = e.device;
            }
            TraceEventKind::Admit { id, .. } => {
                let r = per.entry(id).or_default();
                r.admit = Some(e.t_s);
                r.device = e.device;
            }
            TraceEventKind::PrefillChunk { id, .. } => {
                // Chunks arrive in order; keep the last end time.
                per.entry(id).or_default().prefill_end = Some(e.t_s);
            }
            TraceEventKind::Preempt { id } => {
                per.entry(id).or_default().marks.push((true, e.t_s));
            }
            TraceEventKind::Readmit { id, .. } => {
                per.entry(id).or_default().marks.push((false, e.t_s));
            }
            TraceEventKind::Complete { id, .. } => {
                per.entry(id).or_default().finish = Some(e.t_s);
            }
            // Attribution-only kinds: migration and decode-pool wait
            // fold into the surrounding spans (a migrated request's
            // decode span starts at its prefill end; a swap-in's
            // charge is inside its Readmit span), so the tiling
            // invariant needs no extra marks for them.
            TraceEventKind::DecodeStep { .. }
            | TraceEventKind::EvictBlocks { .. }
            | TraceEventKind::ReuseHit { .. }
            | TraceEventKind::KvHandoff { .. }
            | TraceEventKind::KvMigrate { .. }
            | TraceEventKind::SwapOut { .. }
            | TraceEventKind::SwapIn { .. } => {}
        }
    }
    per.into_iter()
        .filter_map(|(id, r)| {
            let (arrival, admit, finish) = (r.arrival?, r.admit?, r.finish?);
            let mut spans = vec![Span {
                kind: SpanKind::Queue,
                start_s: arrival,
                end_s: admit,
            }];
            let prefill_end = r.prefill_end.unwrap_or(admit);
            spans.push(Span {
                kind: SpanKind::Prefill,
                start_s: admit,
                end_s: prefill_end,
            });
            let mut cur = prefill_end;
            for (is_preempt, t) in r.marks {
                spans.push(Span {
                    // A Preempt mark closes the running decode span; a
                    // Readmit mark closes the preempted span.
                    kind: if is_preempt {
                        SpanKind::Decode
                    } else {
                        SpanKind::Preempted
                    },
                    start_s: cur,
                    end_s: t,
                });
                cur = t;
            }
            spans.push(Span {
                kind: SpanKind::Decode,
                start_s: cur,
                end_s: finish,
            });
            Some(RequestSpans {
                id,
                device: r.device,
                arrival_s: arrival,
                finish_s: finish,
                spans,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t_s: f64, kind: TraceEventKind) -> TraceEvent {
        TraceEvent {
            t_s,
            device: 3,
            kind,
        }
    }

    #[test]
    fn preemption_splits_decode_into_alternating_segments() {
        let id = 7;
        let events = vec![
            ev(0.0, TraceEventKind::Arrival { id, session: 1 }),
            ev(0.5, TraceEventKind::Admit {
                id,
                session: 1,
                reused_tokens: 0,
            }),
            ev(0.8, TraceEventKind::PrefillChunk {
                id,
                from: 0,
                to: 32,
                dt_s: 0.3,
            }),
            ev(1.2, TraceEventKind::Preempt { id }),
            ev(1.9, TraceEventKind::Readmit {
                id,
                recompute_tokens: 40,
                dt_s: 0.4,
            }),
            ev(2.5, TraceEventKind::Complete {
                id,
                tokens_simulated: 16,
            }),
        ];
        let spans = derive_spans(&events);
        assert_eq!(spans.len(), 1);
        let rs = &spans[0];
        assert_eq!(rs.id, id);
        assert_eq!(rs.device, 3);
        assert!(rs.tiles_exactly());
        let kinds: Vec<SpanKind> = rs.spans.iter().map(|s| s.kind).collect();
        assert_eq!(
            kinds,
            vec![
                SpanKind::Queue,
                SpanKind::Prefill,
                SpanKind::Decode,
                SpanKind::Preempted,
                SpanKind::Decode
            ]
        );
        assert!((rs.width_of(SpanKind::Queue) - 0.5).abs() < 1e-12);
        assert!((rs.width_of(SpanKind::Prefill) - 0.3).abs() < 1e-12);
        assert!((rs.width_of(SpanKind::Preempted) - 0.7).abs() < 1e-12);
        assert!((rs.width_of(SpanKind::Decode) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn incomplete_requests_are_skipped() {
        let events = vec![
            ev(0.0, TraceEventKind::Arrival { id: 1, session: 0 }),
            ev(0.1, TraceEventKind::Admit {
                id: 1,
                session: 0,
                reused_tokens: 0,
            }),
            // No Complete — e.g. a budget-truncated run.
            ev(0.0, TraceEventKind::Arrival { id: 2, session: 0 }),
            // Rejected: never admitted.
        ];
        assert!(derive_spans(&events).is_empty());
    }

    #[test]
    fn full_prefix_reuse_yields_a_zero_width_prefill_span() {
        let id = 1;
        let events = vec![
            ev(0.0, TraceEventKind::Arrival { id, session: 0 }),
            ev(0.2, TraceEventKind::Admit {
                id,
                session: 0,
                reused_tokens: 31,
            }),
            ev(1.0, TraceEventKind::Complete {
                id,
                tokens_simulated: 4,
            }),
        ];
        let spans = derive_spans(&events);
        assert_eq!(spans.len(), 1);
        assert!(spans[0].tiles_exactly());
        assert_eq!(spans[0].width_of(SpanKind::Prefill), 0.0);
    }
}
