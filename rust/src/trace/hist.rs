//! Log-bucketed histograms for O(1)-per-sample percentile metrics.
//!
//! [`crate::serve::ServeMetrics`] percentiles used to sort a flat
//! `Vec<f64>` per window — O(n log n) at read time and O(n) memory at
//! million-request scale. A [`Histogram`] instead buckets samples on a
//! geometric grid (`growth = 1.01`, ~1% relative resolution): recording
//! is a `BTreeMap` counter bump, and a percentile is one cumulative walk
//! over the occupied buckets. Percentile semantics match the exact
//! nearest-rank [`crate::serve::percentile`] up to the bucket's
//! quantization (≤ ~0.5% relative, pinned by a regression test in
//! `serve::metrics`).

use std::collections::BTreeMap;

/// Default geometric bucket growth: 1% relative resolution.
const GROWTH: f64 = 1.01;

/// A log-bucketed histogram over non-negative samples (negative and
/// zero samples share one underflow bucket; NaN/infinite samples are
/// dropped).
#[derive(Debug, Clone)]
pub struct Histogram {
    ln_growth: f64,
    /// Occupied buckets: index `i` covers `[growth^i, growth^(i+1))`.
    counts: BTreeMap<i32, u64>,
    /// Samples ≤ 0 (the underflow bucket).
    zeros: u64,
    count: u64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Histogram {
    pub fn new() -> Self {
        Self::with_growth(GROWTH)
    }

    /// A histogram with a custom bucket growth factor (> 1); the
    /// relative quantization error is about `(growth - 1) / 2`.
    pub fn with_growth(growth: f64) -> Self {
        assert!(growth > 1.0, "bucket growth must exceed 1");
        Histogram {
            ln_growth: growth.ln(),
            counts: BTreeMap::new(),
            zeros: 0,
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    /// Record one sample: O(log buckets), no per-sample storage.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if v <= 0.0 {
            self.zeros += 1;
        } else {
            let idx = (v.ln() / self.ln_growth).floor() as i32;
            *self.counts.entry(idx).or_insert(0) += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn min(&self) -> Option<f64> {
        (self.count > 0).then_some(self.min)
    }

    pub fn max(&self) -> Option<f64> {
        (self.count > 0).then_some(self.max)
    }

    /// Exact mean (the sum is tracked outside the buckets).
    pub fn mean(&self) -> Option<f64> {
        (self.count > 0).then(|| self.sum / self.count as f64)
    }

    /// Nearest-rank percentile (`p` in [0, 100]): the same rank rule as
    /// the exact [`crate::serve::percentile`], answered from the bucket
    /// holding that rank. The bucket's representative is its geometric
    /// midpoint, clamped into `[min, max]` so p0/p100 are exact.
    /// Returns `None` on an empty histogram.
    pub fn percentile(&self, p: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((p / 100.0) * (self.count as f64 - 1.0)).round() as u64;
        if rank == 0 {
            return Some(self.min);
        }
        if rank >= self.count - 1 {
            return Some(self.max);
        }
        if rank < self.zeros {
            return Some(self.min.min(0.0));
        }
        let mut cum = self.zeros;
        for (&idx, &c) in &self.counts {
            cum += c;
            if rank < cum {
                let rep = ((idx as f64 + 0.5) * self.ln_growth).exp();
                return Some(rep.max(self.min).min(self.max));
            }
        }
        Some(self.max)
    }

    /// Fold another histogram (same growth) into this one.
    pub fn merge(&mut self, other: &Histogram) {
        assert!(
            (self.ln_growth - other.ln_growth).abs() < 1e-12,
            "cannot merge histograms with different bucket growth"
        );
        for (&idx, &c) in &other.counts {
            *self.counts.entry(idx).or_insert(0) += c;
        }
        self.zeros += other.zeros;
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A named collection of histograms (insertion-ordered), the backing
/// store for metric aggregation: `record("latency", v)` is O(1)-ish per
/// sample regardless of how many samples a window accumulates.
#[derive(Debug, Clone, Default)]
pub struct HistogramRegistry {
    entries: Vec<(String, Histogram)>,
}

impl HistogramRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a sample under `name`, creating the histogram on first
    /// use.
    pub fn record(&mut self, name: &str, v: f64) {
        if let Some((_, h)) = self.entries.iter_mut().find(|(n, _)| n == name) {
            h.record(v);
        } else {
            let mut h = Histogram::new();
            h.record(v);
            self.entries.push((name.to_string(), h));
        }
    }

    pub fn get(&self, name: &str) -> Option<&Histogram> {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, h)| h)
    }

    /// Convenience: percentile of a named histogram, 0.0 when the
    /// histogram is missing or empty (metric-aggregation default).
    pub fn percentile_or_zero(&self, name: &str, p: f64) -> f64 {
        self.get(name)
            .and_then(|h| h.percentile(p))
            .unwrap_or(0.0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.entries.iter().map(|(n, h)| (n.as_str(), h))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_answers_none() {
        let h = Histogram::new();
        assert_eq!(h.percentile(50.0), None);
        assert_eq!(h.mean(), None);
        assert_eq!(h.min(), None);
        assert!(h.is_empty());
    }

    #[test]
    fn percentiles_track_the_exact_nearest_rank_within_bucket_error() {
        // A spread of ~3 decades, including duplicates.
        let samples: Vec<f64> = (1..=400).map(|i| (i as f64 * 0.37).powf(1.7) + 0.01).collect();
        let mut h = Histogram::new();
        for &s in &samples {
            h.record(s);
        }
        for p in [0.0, 10.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let exact = crate::serve::percentile(&samples, p).unwrap();
            let approx = h.percentile(p).unwrap();
            assert!(
                (approx - exact).abs() <= 0.01 * exact.abs().max(1e-12),
                "p{p}: approx {approx} vs exact {exact}"
            );
        }
        assert_eq!(h.count(), 400);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((h.mean().unwrap() - mean).abs() < 1e-9);
    }

    #[test]
    fn zero_samples_land_in_the_underflow_bucket() {
        let mut h = Histogram::new();
        for v in [0.0, 0.0, 0.0, 5.0] {
            h.record(v);
        }
        assert_eq!(h.percentile(0.0), Some(0.0));
        assert_eq!(h.percentile(100.0), Some(5.0));
        // Rank 2 of 4 (p50 → round(1.5) = 2) is still a zero.
        assert_eq!(h.percentile(50.0), Some(0.0));
    }

    #[test]
    fn extremes_are_exact_and_nan_is_dropped() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(3.25);
        h.record(17.5);
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile(0.0), Some(3.25));
        assert_eq!(h.percentile(100.0), Some(17.5));
    }

    #[test]
    fn merge_is_equivalent_to_recording_everything_in_one() {
        let (a_samples, b_samples): (Vec<f64>, Vec<f64>) = (
            (1..50).map(|i| i as f64 * 0.3).collect(),
            (1..80).map(|i| i as f64 * 1.7).collect(),
        );
        let mut merged = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for &v in &a_samples {
            a.record(v);
            merged.record(v);
        }
        for &v in &b_samples {
            b.record(v);
            merged.record(v);
        }
        a.merge(&b);
        for p in [0.0, 50.0, 95.0, 100.0] {
            assert_eq!(a.percentile(p), merged.percentile(p), "p{p}");
        }
        assert_eq!(a.count(), merged.count());
    }

    #[test]
    fn registry_routes_samples_by_name() {
        let mut reg = HistogramRegistry::new();
        reg.record("latency", 1.0);
        reg.record("latency", 3.0);
        reg.record("ttft", 0.5);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.get("latency").unwrap().count(), 2);
        assert_eq!(reg.percentile_or_zero("ttft", 100.0), 0.5);
        assert_eq!(reg.percentile_or_zero("absent", 50.0), 0.0);
    }
}
