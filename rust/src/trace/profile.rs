//! Simulator self-profiling: wall-clock time per engine phase.
//!
//! Orthogonal to sim-time tracing — this measures how fast the
//! *simulator itself* runs, so the smoke suite can publish a
//! `BENCH_simperf.json` the bench-diff gate protects the same way it
//! protects model metrics (at a wider tolerance; wall clock is noisy).
//! The [`crate::serve::DeviceEngine`] accumulates one profile per run
//! with plain `Instant` reads — always on, a few nanoseconds per loop
//! phase, no allocation.

/// Wall-clock seconds spent in each scheduler phase of
/// [`crate::serve::DeviceEngine::run`], plus the simulated-token count
/// that buys the headline simulated-tokens-per-wall-second figure.
/// Phase times do not sum to `wall_s` (retirement and loop bookkeeping
/// are uncounted).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseProfile {
    /// Arrival intake, admission control, and prefill (inline or
    /// chunk-advance) work.
    pub admission_s: f64,
    /// Per-token KV growth (block allocation at token boundaries).
    pub growth_s: f64,
    /// Victim selection + KV drop when growth fails under pressure.
    pub preempt_s: f64,
    /// Batched decode-step costing.
    pub decode_s: f64,
    /// Readmission of preempted requests (recompute charging).
    pub readmit_s: f64,
    /// Total wall clock of the engine run loop.
    pub wall_s: f64,
    /// Tokens whose production was simulated.
    pub sim_tokens: u64,
}

impl PhaseProfile {
    /// Fold another profile in (summing across devices / scenarios).
    pub fn merge(&mut self, other: &PhaseProfile) {
        self.admission_s += other.admission_s;
        self.growth_s += other.growth_s;
        self.preempt_s += other.preempt_s;
        self.decode_s += other.decode_s;
        self.readmit_s += other.readmit_s;
        self.wall_s += other.wall_s;
        self.sim_tokens += other.sim_tokens;
    }

    /// The headline: simulated tokens per wall-clock second.
    pub fn sim_tokens_per_wall_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.sim_tokens as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums_fieldwise() {
        let mut a = PhaseProfile {
            admission_s: 1.0,
            growth_s: 0.5,
            preempt_s: 0.25,
            decode_s: 2.0,
            readmit_s: 0.125,
            wall_s: 4.0,
            sim_tokens: 100,
        };
        let b = PhaseProfile {
            admission_s: 0.5,
            wall_s: 1.0,
            sim_tokens: 50,
            ..PhaseProfile::default()
        };
        a.merge(&b);
        assert!((a.admission_s - 1.5).abs() < 1e-12);
        assert!((a.wall_s - 5.0).abs() < 1e-12);
        assert_eq!(a.sim_tokens, 150);
        assert!((a.sim_tokens_per_wall_s() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn empty_profile_has_zero_rate() {
        assert_eq!(PhaseProfile::default().sim_tokens_per_wall_s(), 0.0);
    }
}
