//! Typed request-lifecycle events.
//!
//! Every event is stamped with the simulated wall clock and the device
//! it happened on; request- and session-scoped kinds carry their ids.
//! The stream is append-only and chronological per device (a
//! [`crate::serve::Cluster`] runs its devices sequentially, so one
//! request's events are always in order even when devices interleave in
//! the recorded stream).

/// One lifecycle event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Simulated wall-clock seconds.
    pub t_s: f64,
    /// Index of the device the event happened on.
    pub device: usize,
    pub kind: TraceEventKind,
}

/// What happened. Durations (`dt_s`) are the simulated service time the
/// event charged; the event is stamped at the *end* of that charge, so
/// a charged event spans `[t_s - dt_s, t_s]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEventKind {
    /// A request entered the device's arrival queue.
    Arrival { id: u64, session: u64 },
    /// Admission control granted KV; `reused_tokens` of the prompt were
    /// reclaimed from session residency (paged KV prefix reuse).
    Admit {
        id: u64,
        session: u64,
        reused_tokens: usize,
    },
    /// One prefill chunk `[from, to)` of the request's prompt finished
    /// (inline prefill emits a single chunk covering the whole prompt).
    PrefillChunk {
        id: u64,
        from: usize,
        to: usize,
        dt_s: f64,
    },
    /// One batched decode step over `batch` in-flight requests.
    DecodeStep { batch: usize, dt_s: f64 },
    /// The request was preempted: its KV blocks were dropped and it
    /// moved to the readmission queue.
    Preempt { id: u64 },
    /// Readmission after preemption: `recompute_tokens` (prompt plus
    /// every token generated so far) were re-prefilled over `dt_s`.
    Readmit {
        id: u64,
        recompute_tokens: usize,
        dt_s: f64,
    },
    /// The paged allocator evicted an idle session residency under
    /// capacity pressure.
    EvictBlocks { session: u64, blocks: usize },
    /// Admission reclaimed `tokens` of session-resident KV prefix, so
    /// that much prefill was skipped.
    ReuseHit { id: u64, session: u64, tokens: usize },
    /// Prefill→decode KV handoff over the host link (hetero backend);
    /// the cost is part of the prefill charge, reported here for
    /// attribution.
    KvHandoff { id: u64, tokens: usize, dt_s: f64 },
    /// Paged KV blocks migrated across the host fabric from the
    /// prefill-pool device to the decode device that finishes the
    /// request (disaggregated serving); spans `[t_s - dt_s, t_s]`.
    KvMigrate { id: u64, tokens: usize, dt_s: f64 },
    /// A preempted request's KV block payloads were spilled to the
    /// host buffer over the fabric (asynchronous DMA: charged to the
    /// link, not the engine clock).
    SwapOut { id: u64, tokens: usize, dt_s: f64 },
    /// Readmission restored swapped-out KV from the host buffer
    /// instead of recomputing it (the fabric read was cheaper); the
    /// charge is part of the readmit span, reported for attribution.
    SwapIn { id: u64, tokens: usize, dt_s: f64 },
    /// The request finished; `tokens_simulated` tokens were produced.
    Complete { id: u64, tokens_simulated: usize },
}

impl TraceEventKind {
    /// Short kind label (Chrome trace names, docs, tests).
    pub fn name(&self) -> &'static str {
        match self {
            TraceEventKind::Arrival { .. } => "arrival",
            TraceEventKind::Admit { .. } => "admit",
            TraceEventKind::PrefillChunk { .. } => "prefill",
            TraceEventKind::DecodeStep { .. } => "decode",
            TraceEventKind::Preempt { .. } => "preempt",
            TraceEventKind::Readmit { .. } => "readmit",
            TraceEventKind::EvictBlocks { .. } => "evict",
            TraceEventKind::ReuseHit { .. } => "reuse",
            TraceEventKind::KvHandoff { .. } => "kv_handoff",
            TraceEventKind::KvMigrate { .. } => "kv_migrate",
            TraceEventKind::SwapOut { .. } => "swap_out",
            TraceEventKind::SwapIn { .. } => "swap_in",
            TraceEventKind::Complete { .. } => "complete",
        }
    }

    /// The request the event belongs to, when it names one
    /// (device-level and session-level events return `None`).
    pub fn request_id(&self) -> Option<u64> {
        match self {
            TraceEventKind::Arrival { id, .. }
            | TraceEventKind::Admit { id, .. }
            | TraceEventKind::PrefillChunk { id, .. }
            | TraceEventKind::Preempt { id }
            | TraceEventKind::Readmit { id, .. }
            | TraceEventKind::ReuseHit { id, .. }
            | TraceEventKind::KvHandoff { id, .. }
            | TraceEventKind::KvMigrate { id, .. }
            | TraceEventKind::SwapOut { id, .. }
            | TraceEventKind::SwapIn { id, .. }
            | TraceEventKind::Complete { id, .. } => Some(*id),
            TraceEventKind::DecodeStep { .. } | TraceEventKind::EvictBlocks { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_request_ids_cover_every_kind() {
        let kinds = [
            TraceEventKind::Arrival { id: 1, session: 2 },
            TraceEventKind::Admit {
                id: 1,
                session: 2,
                reused_tokens: 0,
            },
            TraceEventKind::PrefillChunk {
                id: 1,
                from: 0,
                to: 32,
                dt_s: 0.1,
            },
            TraceEventKind::DecodeStep { batch: 4, dt_s: 0.01 },
            TraceEventKind::Preempt { id: 1 },
            TraceEventKind::Readmit {
                id: 1,
                recompute_tokens: 40,
                dt_s: 0.2,
            },
            TraceEventKind::EvictBlocks {
                session: 2,
                blocks: 3,
            },
            TraceEventKind::ReuseHit {
                id: 1,
                session: 2,
                tokens: 16,
            },
            TraceEventKind::KvHandoff {
                id: 1,
                tokens: 32,
                dt_s: 0.001,
            },
            TraceEventKind::KvMigrate {
                id: 1,
                tokens: 33,
                dt_s: 0.002,
            },
            TraceEventKind::SwapOut {
                id: 1,
                tokens: 40,
                dt_s: 0.003,
            },
            TraceEventKind::SwapIn {
                id: 1,
                tokens: 40,
                dt_s: 0.003,
            },
            TraceEventKind::Complete {
                id: 1,
                tokens_simulated: 8,
            },
        ];
        let names: Vec<&str> = kinds.iter().map(|k| k.name()).collect();
        assert_eq!(
            names,
            vec![
                "arrival",
                "admit",
                "prefill",
                "decode",
                "preempt",
                "readmit",
                "evict",
                "reuse",
                "kv_handoff",
                "kv_migrate",
                "swap_out",
                "swap_in",
                "complete"
            ]
        );
        for k in &kinds {
            match k {
                TraceEventKind::DecodeStep { .. } | TraceEventKind::EvictBlocks { .. } => {
                    assert_eq!(k.request_id(), None)
                }
                _ => assert_eq!(k.request_id(), Some(1)),
            }
        }
    }
}
