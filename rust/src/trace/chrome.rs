//! Chrome `trace_event`-format JSON export.
//!
//! Renders an event stream as a JSON Object Format trace document
//! (`{"displayTimeUnit": "ms", "traceEvents": [...]}`) loadable in
//! `chrome://tracing` or Perfetto:
//!
//! * one **thread track per device** (`tid` = device index) carrying
//!   complete (`"X"`) events for prefill chunks, decode steps, KV
//!   handoffs, fabric migrations, swap-outs/ins and readmit
//!   recomputes, plus instant (`"i"`) events for arrivals,
//!   preemptions, evictions and reuse hits;
//! * one **async group per request** (`cat: "request"`, `id` = request
//!   id) spanning `[arrival, finish]`, with nested async spans for the
//!   derived queue/prefill/decode/preempted phases
//!   ([`super::span::derive_spans`]).
//!
//! Timestamps are microseconds of simulated time (`ts = t_s · 1e6`);
//! charged events start at `t_s - dt_s`.

use super::event::{TraceEvent, TraceEventKind};
use super::span::derive_spans;
use std::collections::BTreeSet;

const US: f64 = 1e6;

/// Render the event stream as a Chrome trace_event JSON document.
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    let mut rows: Vec<String> = Vec::new();
    rows.push(
        "{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": 0, \"tid\": 0, \
         \"args\": {\"name\": \"sal-pim simulated cluster\"}}"
            .to_string(),
    );
    let devices: BTreeSet<usize> = events.iter().map(|e| e.device).collect();
    for d in devices {
        rows.push(format!(
            "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": 0, \"tid\": {d}, \
             \"args\": {{\"name\": \"device {d}\"}}}}"
        ));
    }
    for e in events {
        let d = e.device;
        let name = e.kind.name();
        match e.kind {
            TraceEventKind::PrefillChunk { id, from, to, dt_s } => rows.push(format!(
                "{{\"name\": \"{name}\", \"cat\": \"device\", \"ph\": \"X\", \"pid\": 0, \
                 \"tid\": {d}, \"ts\": {:.3}, \"dur\": {:.3}, \
                 \"args\": {{\"id\": {id}, \"from\": {from}, \"to\": {to}}}}}",
                (e.t_s - dt_s) * US,
                dt_s * US
            )),
            TraceEventKind::DecodeStep { batch, dt_s } => rows.push(format!(
                "{{\"name\": \"{name}\", \"cat\": \"device\", \"ph\": \"X\", \"pid\": 0, \
                 \"tid\": {d}, \"ts\": {:.3}, \"dur\": {:.3}, \"args\": {{\"batch\": {batch}}}}}",
                (e.t_s - dt_s) * US,
                dt_s * US
            )),
            TraceEventKind::Readmit {
                id,
                recompute_tokens,
                dt_s,
            } => rows.push(format!(
                "{{\"name\": \"{name}\", \"cat\": \"device\", \"ph\": \"X\", \"pid\": 0, \
                 \"tid\": {d}, \"ts\": {:.3}, \"dur\": {:.3}, \
                 \"args\": {{\"id\": {id}, \"recompute_tokens\": {recompute_tokens}}}}}",
                (e.t_s - dt_s) * US,
                dt_s * US
            )),
            TraceEventKind::KvHandoff { id, tokens, dt_s }
            | TraceEventKind::KvMigrate { id, tokens, dt_s }
            | TraceEventKind::SwapOut { id, tokens, dt_s }
            | TraceEventKind::SwapIn { id, tokens, dt_s } => rows.push(format!(
                "{{\"name\": \"{name}\", \"cat\": \"device\", \"ph\": \"X\", \"pid\": 0, \
                 \"tid\": {d}, \"ts\": {:.3}, \"dur\": {:.3}, \
                 \"args\": {{\"id\": {id}, \"tokens\": {tokens}}}}}",
                (e.t_s - dt_s) * US,
                dt_s * US
            )),
            TraceEventKind::Arrival { id, session } => rows.push(instant(
                name,
                d,
                e.t_s,
                &format!("\"id\": {id}, \"session\": {session}"),
            )),
            TraceEventKind::Admit {
                id,
                session,
                reused_tokens,
            } => rows.push(instant(
                name,
                d,
                e.t_s,
                &format!(
                    "\"id\": {id}, \"session\": {session}, \"reused_tokens\": {reused_tokens}"
                ),
            )),
            TraceEventKind::Preempt { id } => {
                rows.push(instant(name, d, e.t_s, &format!("\"id\": {id}")))
            }
            TraceEventKind::EvictBlocks { session, blocks } => rows.push(instant(
                name,
                d,
                e.t_s,
                &format!("\"session\": {session}, \"blocks\": {blocks}"),
            )),
            TraceEventKind::ReuseHit {
                id,
                session,
                tokens,
            } => rows.push(instant(
                name,
                d,
                e.t_s,
                &format!("\"id\": {id}, \"session\": {session}, \"tokens\": {tokens}"),
            )),
            TraceEventKind::Complete {
                id,
                tokens_simulated,
            } => rows.push(instant(
                name,
                d,
                e.t_s,
                &format!("\"id\": {id}, \"tokens_simulated\": {tokens_simulated}"),
            )),
        }
    }
    // Async lifetime group + derived phase spans, one group per request.
    for rs in derive_spans(events) {
        let (id, d) = (rs.id, rs.device);
        rows.push(async_mark("b", &format!("req {id}"), id, d, rs.arrival_s));
        for s in &rs.spans {
            rows.push(async_mark("b", s.kind.name(), id, d, s.start_s));
            rows.push(async_mark("e", s.kind.name(), id, d, s.end_s));
        }
        rows.push(async_mark("e", &format!("req {id}"), id, d, rs.finish_s));
    }
    format!(
        "{{\"displayTimeUnit\": \"ms\", \"traceEvents\": [\n{}\n]}}\n",
        rows.join(",\n")
    )
}

fn instant(name: &str, device: usize, t_s: f64, args: &str) -> String {
    format!(
        "{{\"name\": \"{name}\", \"cat\": \"device\", \"ph\": \"i\", \"s\": \"t\", \
         \"pid\": 0, \"tid\": {device}, \"ts\": {:.3}, \"args\": {{{args}}}}}",
        t_s * US
    )
}

fn async_mark(ph: &str, name: &str, id: u64, device: usize, t_s: f64) -> String {
    format!(
        "{{\"name\": \"{name}\", \"cat\": \"request\", \"ph\": \"{ph}\", \"id\": {id}, \
         \"pid\": 0, \"tid\": {device}, \"ts\": {:.3}}}",
        t_s * US
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        let id = 5;
        vec![
            TraceEvent {
                t_s: 0.0,
                device: 0,
                kind: TraceEventKind::Arrival { id, session: 2 },
            },
            TraceEvent {
                t_s: 0.1,
                device: 0,
                kind: TraceEventKind::Admit {
                    id,
                    session: 2,
                    reused_tokens: 0,
                },
            },
            TraceEvent {
                t_s: 0.4,
                device: 0,
                kind: TraceEventKind::PrefillChunk {
                    id,
                    from: 0,
                    to: 32,
                    dt_s: 0.3,
                },
            },
            TraceEvent {
                t_s: 0.5,
                device: 0,
                kind: TraceEventKind::DecodeStep { batch: 1, dt_s: 0.1 },
            },
            TraceEvent {
                t_s: 0.5,
                device: 0,
                kind: TraceEventKind::Complete {
                    id,
                    tokens_simulated: 2,
                },
            },
        ]
    }

    #[test]
    fn export_is_valid_json_with_the_expected_tracks() {
        let doc = chrome_trace_json(&sample_events());
        let json = crate::scenario::compare::parse_json(&doc).expect("valid JSON");
        assert_eq!(
            json.get("displayTimeUnit").and_then(|v| v.as_str()),
            Some("ms")
        );
        let events = json
            .get("traceEvents")
            .and_then(|v| v.as_arr())
            .expect("traceEvents array");
        // Metadata + device events + async group (lifetime pair + 3
        // derived spans × b/e).
        assert!(events.len() >= 10, "{}", events.len());
        let phases: Vec<&str> = events
            .iter()
            .filter_map(|e| e.get("ph").and_then(|v| v.as_str()))
            .collect();
        for ph in ["M", "X", "i", "b", "e"] {
            assert!(phases.contains(&ph), "missing ph {ph}: {phases:?}");
        }
        // Async begin/end marks must balance.
        let b = phases.iter().filter(|p| **p == "b").count();
        let e = phases.iter().filter(|p| **p == "e").count();
        assert_eq!(b, e);
    }

    #[test]
    fn charged_events_start_at_t_minus_dt() {
        let doc = chrome_trace_json(&sample_events());
        let json = crate::scenario::compare::parse_json(&doc).unwrap();
        let events = json.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let prefill = events
            .iter()
            .find(|e| {
                e.get("name").and_then(|v| v.as_str()) == Some("prefill")
                    && e.get("ph").and_then(|v| v.as_str()) == Some("X")
            })
            .expect("prefill X event");
        let ts = prefill.get("ts").and_then(|v| v.as_f64()).unwrap();
        let dur = prefill.get("dur").and_then(|v| v.as_f64()).unwrap();
        assert!((ts - 0.1 * US).abs() < 1e-6);
        assert!((dur - 0.3 * US).abs() < 1e-6);
    }
}
