//! Request-lifecycle tracing and simulator self-profiling (L5).
//!
//! The serving stack's end-of-run aggregates say *that* a run was slow,
//! never *why* — was a request queued, preempted twice, chunk-starved?
//! This module is the observability layer underneath those aggregates:
//!
//! * [`event`] — typed lifecycle events ([`TraceEventKind`]: arrival,
//!   admit, prefill chunk, decode step, preempt, readmit, evict, reuse
//!   hit, KV handoff, complete), each stamped with sim-time and device;
//! * [`TraceSink`] / [`Recorder`] — where events land. Tracing is
//!   **off by default**: an engine without a [`TraceHandle`] pays one
//!   `Option` check per emission site and allocates nothing;
//! * [`span`] — per-request timelines derived from the stream, whose
//!   queue/prefill/decode/preempted spans tile `[arrival, finish]`
//!   exactly ([`RequestSpans::tiles_exactly`]);
//! * [`chrome`] — Chrome `trace_event` JSON export (`--trace FILE` on
//!   `sal-pim serve` / `sal-pim run`), loadable in `chrome://tracing`
//!   or Perfetto: one track per device, async spans per request;
//! * [`hist`] — log-bucketed [`Histogram`]s backing
//!   [`crate::serve::ServeMetrics`] percentiles at O(1) per sample;
//! * [`profile`] — wall-clock self-profiling per engine phase
//!   ([`PhaseProfile`]), published by the smoke suite as
//!   `BENCH_simperf.json` and gated by the bench-diff CI job.

pub mod chrome;
pub mod event;
pub mod hist;
pub mod profile;
pub mod span;

pub use chrome::chrome_trace_json;
pub use event::{TraceEvent, TraceEventKind};
pub use hist::{Histogram, HistogramRegistry};
pub use profile::PhaseProfile;
pub use span::{derive_spans, RequestSpans, Span, SpanKind};

use std::cell::RefCell;
use std::rc::Rc;

/// Where lifecycle events land.
pub trait TraceSink {
    fn emit(&mut self, event: TraceEvent);
}

/// The default sink: an in-memory, append-only event log.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    events: Vec<TraceEvent>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Drain the recorded events.
    pub fn take(&mut self) -> Vec<TraceEvent> {
        std::mem::take(&mut self.events)
    }
}

impl TraceSink for Recorder {
    fn emit(&mut self, event: TraceEvent) {
        self.events.push(event);
    }
}

/// A cheap, cloneable handle every emitter shares.
///
/// The serving stack is single-threaded (a [`crate::serve::Cluster`]
/// runs its devices sequentially), so the handle is an
/// `Rc<RefCell<..>>` around a [`Recorder`] plus the current sim-time /
/// device stamp. The engine keeps the stamp fresh
/// ([`TraceHandle::set_time`] / [`TraceHandle::set_device`]) so nested
/// emitters — the paged KV allocator emitting evictions and reuse hits
/// mid-admission — need no clock plumbing of their own.
#[derive(Debug, Clone, Default)]
pub struct TraceHandle {
    inner: Rc<RefCell<TraceCtx>>,
}

#[derive(Debug, Default)]
struct TraceCtx {
    recorder: Recorder,
    t_s: f64,
    device: usize,
}

impl TraceHandle {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stamp subsequent events with this device index.
    pub fn set_device(&self, device: usize) {
        self.inner.borrow_mut().device = device;
    }

    /// Advance the sim-time stamp for subsequent [`TraceHandle::emit`]s.
    pub fn set_time(&self, t_s: f64) {
        self.inner.borrow_mut().t_s = t_s;
    }

    /// Emit `kind` at the current sim-time / device stamp.
    pub fn emit(&self, kind: TraceEventKind) {
        let mut ctx = self.inner.borrow_mut();
        let (t_s, device) = (ctx.t_s, ctx.device);
        ctx.recorder.emit(TraceEvent { t_s, device, kind });
    }

    /// Emit at an explicit sim-time (arrivals predate the clock).
    pub fn emit_at(&self, t_s: f64, kind: TraceEventKind) {
        let mut ctx = self.inner.borrow_mut();
        let device = ctx.device;
        ctx.recorder.emit(TraceEvent { t_s, device, kind });
    }

    /// Drain every event recorded so far.
    pub fn take_events(&self) -> Vec<TraceEvent> {
        self.inner.borrow_mut().recorder.take()
    }

    /// Number of events currently recorded.
    pub fn len(&self) -> usize {
        self.inner.borrow().recorder.events().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_clones_share_one_recorder() {
        let h = TraceHandle::new();
        let clone = h.clone();
        h.set_device(2);
        h.set_time(1.5);
        clone.emit(TraceEventKind::Preempt { id: 9 });
        h.emit_at(0.25, TraceEventKind::Arrival { id: 9, session: 1 });
        assert_eq!(h.len(), 2);
        let events = h.take_events();
        assert!(clone.is_empty(), "take drains the shared recorder");
        assert_eq!(events[0].device, 2);
        assert_eq!(events[0].t_s, 1.5);
        assert_eq!(events[1].t_s, 0.25);
        assert_eq!(events[1].kind.request_id(), Some(9));
    }

    #[test]
    fn recorder_implements_the_sink_trait() {
        fn fill(sink: &mut dyn TraceSink) {
            sink.emit(TraceEvent {
                t_s: 0.0,
                device: 0,
                kind: TraceEventKind::DecodeStep { batch: 2, dt_s: 0.1 },
            });
        }
        let mut r = Recorder::new();
        fill(&mut r);
        assert_eq!(r.events().len(), 1);
        assert_eq!(r.take().len(), 1);
        assert!(r.events().is_empty());
    }
}
