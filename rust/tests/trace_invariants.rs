//! Trace-subsystem invariants across the serving stack: every completed
//! request's derived spans must tile `[arrival, finish]` exactly (even
//! under chunked prefill + preemption + readmission), tracing must never
//! perturb the simulated numbers, the Chrome export must be valid
//! trace_event JSON, and wall-clock budgets must truncate runs cleanly.

use sal_pim::config::SimConfig;
use sal_pim::scenario::{
    compare::parse_json, sink, ConfigSel, EngineKind, Runner, Scenario, ServeParams,
};
use sal_pim::serve::{
    Cluster, Completion, DeviceEngine, DisaggregatedCluster, EvictPolicy, FabricParams,
    KvPolicy, PrefixCacheMode, Request, Routing, SloClass,
};
use sal_pim::trace::{
    chrome_trace_json, derive_spans, SpanKind, TraceEvent, TraceEventKind, TraceHandle,
};

fn req(id: u64, session: u64, prompt: usize, out: usize, at: f64) -> Request {
    Request {
        id,
        prompt_len: prompt,
        max_new_tokens: out,
        arrival_s: at,
        session,
        slo: SloClass::Batch,
        prefix: Vec::new(),
    }
}

/// Subarrays one `tokens`-wide window pins (the whole-window unit).
fn subarrays_for(cfg: &SimConfig, tokens: usize) -> usize {
    (tokens * cfg.model.kv_bytes_per_token()).div_ceil(cfg.hbm.subarray_bytes())
}

/// A preemption-heavy traced run: chunked prefill, paged KV sized for
/// ~2.5 of the 6 decoding windows.
fn contended_run() -> (Vec<Completion>, Vec<TraceEvent>, usize) {
    let cfg = SimConfig::paper();
    let tight = subarrays_for(&cfg, 16 + 32) * 5 / 2;
    let mut eng = DeviceEngine::new(&cfg, 8)
        .with_kv_policy(KvPolicy::Paged)
        .with_kv_subarrays(tight)
        .with_prefill_chunk(Some(8));
    let trace = TraceHandle::new();
    eng.set_trace(trace.clone());
    for i in 0..6 {
        eng.submit(req(i, i, 16, 32, i as f64 * 1e-4));
    }
    let done = eng.run();
    let preemptions = eng.report().preemptions;
    (done, trace.take_events(), preemptions)
}

#[test]
fn spans_tile_arrival_to_finish_under_preemption() {
    let (done, events, preemptions) = contended_run();
    assert!(preemptions > 0, "scenario must exercise preemption");
    assert!(
        events
            .iter()
            .any(|e| matches!(e.kind, TraceEventKind::Readmit { .. })),
        "scenario must exercise readmission"
    );
    let spans = derive_spans(&events);
    assert_eq!(spans.len(), done.len(), "one timeline per completion");
    for rs in &spans {
        assert!(rs.tiles_exactly(), "request {} spans leave gaps: {rs:?}", rs.id);
        let c = done.iter().find(|c| c.id == rs.id).unwrap();
        // Span widths must reproduce the completion's own accounting:
        // queue and prefill are single spans built from the same floats,
        // so they match bit-for-bit; the decode/preempted alternation
        // re-sums segment widths, so it matches to accumulation error.
        assert_eq!(rs.finish_s, c.finish_s, "req {}", rs.id);
        assert_eq!(rs.width_of(SpanKind::Queue), c.queue_s, "req {}", rs.id);
        assert_eq!(rs.width_of(SpanKind::Prefill), c.prefill_s, "req {}", rs.id);
        let decode_like =
            rs.width_of(SpanKind::Decode) + rs.width_of(SpanKind::Preempted);
        assert!(
            (decode_like - c.decode_s).abs() < 1e-9,
            "req {}: decode+preempted {decode_like} vs decode_s {}",
            rs.id,
            c.decode_s
        );
    }
    // A preempted request's timeline must actually alternate.
    assert!(
        spans
            .iter()
            .any(|rs| rs.spans.iter().any(|s| s.kind == SpanKind::Preempted)),
        "no preempted span despite {preemptions} preemptions"
    );
}

#[test]
fn complete_events_conserve_simulated_tokens() {
    let (done, events, _) = contended_run();
    let traced: u64 = events
        .iter()
        .filter_map(|e| match e.kind {
            TraceEventKind::Complete {
                tokens_simulated, ..
            } => Some(tokens_simulated as u64),
            _ => None,
        })
        .sum();
    let simulated: u64 = done.iter().map(|c| c.tokens_simulated as u64).sum();
    assert_eq!(traced, simulated);
    // Decode steps account for every token not produced by a prefill.
    let decoded: u64 = events
        .iter()
        .filter_map(|e| match e.kind {
            TraceEventKind::DecodeStep { batch, .. } => Some(batch as u64),
            _ => None,
        })
        .sum();
    let first_tokens = done.len() as u64;
    assert_eq!(decoded + first_tokens, simulated);
}

#[test]
fn tracing_never_perturbs_the_simulation() {
    let run = |traced: bool| {
        let cfg = SimConfig::paper();
        let mut c = Cluster::new(&cfg, 2, 4, Routing::SessionAffinity).with_kv(
            KvPolicy::Paged,
            EvictPolicy::Lru,
            PrefixCacheMode::Session,
            None,
            None,
        );
        let handle = traced.then(TraceHandle::new);
        if let Some(t) = &handle {
            c.set_trace(t.clone());
        }
        for i in 0..12u64 {
            c.submit(req(i, i % 3, 12, 8, i as f64 * 0.01));
        }
        let bits: Vec<(u64, usize, u64, u64, u64, usize)> = c
            .run()
            .iter()
            .map(|d| {
                (
                    d.id,
                    d.tokens_simulated,
                    d.queue_s.to_bits(),
                    d.prefill_s.to_bits(),
                    d.finish_s.to_bits(),
                    d.device,
                )
            })
            .collect();
        (bits, handle.map(|t| t.len()).unwrap_or(0))
    };
    let (quiet, none) = run(false);
    let (traced, some) = run(true);
    assert_eq!(none, 0);
    assert!(some > 0, "traced run recorded nothing");
    assert_eq!(quiet, traced, "tracing changed simulated completions");
}

#[test]
fn cluster_trace_stamps_per_device_tracks() {
    let cfg = SimConfig::paper();
    let mut c = Cluster::new(&cfg, 2, 4, Routing::RoundRobin);
    let trace = TraceHandle::new();
    c.set_trace(trace.clone());
    for i in 0..8u64 {
        c.submit(req(i, i, 12, 6, 0.0));
    }
    let done = c.run();
    let events = trace.take_events();
    let spans = derive_spans(&events);
    assert_eq!(spans.len(), done.len());
    for rs in &spans {
        let c = done.iter().find(|c| c.id == rs.id).unwrap();
        assert_eq!(rs.device, c.device, "req {} on the wrong track", rs.id);
    }
    let devices: std::collections::BTreeSet<usize> =
        spans.iter().map(|rs| rs.device).collect();
    assert_eq!(devices.len(), 2, "round-robin must populate both tracks");
}

#[test]
fn chrome_export_is_valid_and_loadable() {
    let (_, events, _) = contended_run();
    let doc = chrome_trace_json(&events);
    let json = parse_json(&doc).expect("chrome trace must be valid JSON");
    assert_eq!(
        json.get("displayTimeUnit").and_then(|v| v.as_str()),
        Some("ms")
    );
    let rows = json
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents array");
    // Async begin/end marks balance, and every complete event has a
    // non-negative duration starting at ts >= 0.
    let ph = |row: &sal_pim::scenario::compare::Json| {
        row.get("ph").and_then(|v| v.as_str()).unwrap_or("").to_string()
    };
    let begins = rows.iter().filter(|r| ph(r) == "b").count();
    let ends = rows.iter().filter(|r| ph(r) == "e").count();
    assert!(begins > 0);
    assert_eq!(begins, ends);
    for r in rows.iter().filter(|r| ph(r) == "X") {
        let ts = r.get("ts").and_then(|v| v.as_f64()).unwrap();
        let dur = r.get("dur").and_then(|v| v.as_f64()).unwrap();
        assert!(ts >= 0.0 && dur >= 0.0, "negative charge: ts={ts} dur={dur}");
    }
}

#[test]
fn disagg_spans_tile_arrival_to_finish_through_migration_and_swap() {
    // A migrated (and possibly swapped) request still has one Arrival,
    // one Admit, one Complete in the merged stream, its KvMigrate /
    // SwapOut / SwapIn charges are attribution-only, and its derived
    // spans tile [arrival, finish] exactly — the migration delay and
    // the decode-pool wait fold into the decode span, matching the
    // merged completion's own accounting.
    let cfg = SimConfig::paper();
    let tight = subarrays_for(&cfg, 16 + 32) * 5 / 2;
    let mut c = DisaggregatedCluster::new(&cfg, 1, 1, 8, FabricParams::pcie()).with_kv(
        KvPolicy::Paged,
        EvictPolicy::Swap,
        PrefixCacheMode::Session,
        None,
        Some(tight),
    );
    let trace = TraceHandle::new();
    c.set_trace(trace.clone());
    for i in 0..6 {
        c.submit(req(i, i, 16, 32, i as f64 * 1e-4));
    }
    let done = c.run();
    assert_eq!(done.len(), 6);
    let events = trace.take_events();

    let count = |pred: &dyn Fn(&TraceEventKind) -> bool| {
        events.iter().filter(|e| pred(&e.kind)).count()
    };
    assert_eq!(
        count(&|k| matches!(k, TraceEventKind::Arrival { .. })),
        6,
        "each request arrives once in the merged stream"
    );
    assert_eq!(count(&|k| matches!(k, TraceEventKind::Complete { .. })), 6);
    assert_eq!(
        count(&|k| matches!(k, TraceEventKind::KvMigrate { .. })),
        6,
        "every request's KV crosses the fabric exactly once"
    );
    let preemptions: usize = c.per_device_reports().iter().map(|r| r.preemptions).sum();
    assert!(preemptions > 0, "the shrunken decode region must preempt");
    assert!(
        count(&|k| matches!(k, TraceEventKind::SwapOut { .. })) > 0,
        "preemption under swap eviction must spill to host"
    );

    let spans = derive_spans(&events);
    assert_eq!(spans.len(), done.len(), "one timeline per completion");
    for rs in &spans {
        assert!(rs.tiles_exactly(), "request {} spans leave gaps: {rs:?}", rs.id);
        let d = done.iter().find(|d| d.id == rs.id).unwrap();
        assert_eq!(rs.finish_s, d.finish_s, "req {}", rs.id);
        assert_eq!(rs.width_of(SpanKind::Queue), d.queue_s, "req {}", rs.id);
        assert_eq!(rs.width_of(SpanKind::Prefill), d.prefill_s, "req {}", rs.id);
        let decode_like = rs.width_of(SpanKind::Decode) + rs.width_of(SpanKind::Preempted);
        assert!(
            (decode_like - d.decode_s).abs() < 1e-9,
            "req {}: decode+preempted {decode_like} vs decode_s {}",
            rs.id,
            d.decode_s
        );
    }
}

#[test]
fn budget_truncation_is_recorded_in_provenance_json() {
    let scenario = Scenario::Serve(
        ServeParams::default()
            .with_config(ConfigSel::preset("mini").with_budget_s(0.0))
            .with_engine(EngineKind::Batch)
            .with_workload(6, 7)
            .with_at_once(true),
    );
    let (out, aux) = Runner::new().run_with(&scenario, false).unwrap();
    assert!(aux.truncated);
    assert!(out.provenance.truncated);
    let json = sink::to_json(&out);
    assert!(json.contains("\"truncated\": true"), "{json}");
    // An unbudgeted run of the same scenario stays untruncated.
    let free = Scenario::Serve(
        ServeParams::default()
            .with_config(ConfigSel::preset("mini"))
            .with_engine(EngineKind::Batch)
            .with_workload(6, 7)
            .with_at_once(true),
    );
    let (out, aux) = Runner::new().run_with(&free, false).unwrap();
    assert!(!aux.truncated && !out.provenance.truncated);
    assert!(sink::to_json(&out).contains("\"truncated\": false"));
}
