//! Property tests over the DRAM timing model: the burst fast path must
//! match per-command issue exactly, and protocol invariants must hold on
//! random command sequences.

use sal_pim::config::SimConfig;
use sal_pim::dram::{ChannelController, CmdTarget, DramCmd};
use sal_pim::stats::Stats;
use sal_pim::testutil::forall;

#[test]
fn stream_cols_equals_per_command_on_random_workloads() {
    let cfg = SimConfig::paper();
    forall(150, |g| {
        let su = g.usize_in(0, 63);
        let row = g.usize_in(0, 511);
        let n = g.u64_in(1, 32);
        let write = g.bool();
        let target = if g.bool() {
            CmdTarget::AllBanks
        } else {
            CmdTarget::Bank(g.usize_in(0, 15))
        };

        let mut a = ChannelController::new(&cfg);
        let mut b = ChannelController::new(&cfg);
        let mut sa = Stats::new();
        let mut sb = Stats::new();
        for (c, st) in [(&mut a, &mut sa), (&mut b, &mut sb)] {
            c.issue(
                DramCmd::Act {
                    target,
                    subarray: su,
                    row,
                },
                st,
            )
            .unwrap();
        }
        let last_a = a.stream_cols(target, su, n, write, &mut sa).unwrap();
        let mut last_b = 0;
        for col in 0..n {
            let cmd = if write {
                DramCmd::Wr {
                    target,
                    subarray: su,
                    col: col as usize,
                }
            } else {
                DramCmd::Rd {
                    target,
                    subarray: su,
                    col: col as usize,
                }
            };
            last_b = b.issue(cmd, &mut sb).unwrap();
        }
        assert_eq!(last_a, last_b, "fast path diverged (n={n}, write={write})");
        assert_eq!(sa.internal_bytes, sb.internal_bytes);
        assert_eq!(sa.commands, sb.commands);
        // Follow-up PRE must land at the same cycle in both worlds.
        let pa = a
            .issue(DramCmd::Pre { target, subarray: su }, &mut sa)
            .unwrap();
        let pb = b
            .issue(DramCmd::Pre { target, subarray: su }, &mut sb)
            .unwrap();
        assert_eq!(pa, pb);
    });
}

#[test]
fn interleaved_stream_equals_round_robin_issue() {
    let cfg = SimConfig::paper();
    forall(100, |g| {
        let n_groups = g.usize_in(1, 4);
        let sus: Vec<usize> = (0..n_groups).map(|i| i * 15).collect();
        let n = g.u64_in(1, 24);

        let mut a = ChannelController::new(&cfg);
        let mut b = ChannelController::new(&cfg);
        let mut sa = Stats::new();
        let mut sb = Stats::new();
        for (c, st) in [(&mut a, &mut sa), (&mut b, &mut sb)] {
            for (i, &su) in sus.iter().enumerate() {
                c.issue(
                    DramCmd::Act {
                        target: CmdTarget::AllBanks,
                        subarray: su,
                        row: i,
                    },
                    st,
                )
                .unwrap();
            }
        }
        let last_a = a.stream_interleaved(&sus, n, false, &mut sa).unwrap();
        let mut last_b = 0;
        for col in 0..n {
            for &su in &sus {
                last_b = b
                    .issue(
                        DramCmd::Rd {
                            target: CmdTarget::AllBanks,
                            subarray: su,
                            col: col as usize,
                        },
                        &mut sb,
                    )
                    .unwrap();
            }
        }
        assert_eq!(last_a, last_b);
        assert_eq!(a.clock, b.clock);
        assert_eq!(sa.internal_bytes, sb.internal_bytes);
    });
}

#[test]
fn protocol_invariants_on_random_sequences() {
    let cfg = SimConfig::paper();
    let t = cfg.timing;
    forall(120, |g| {
        let mut c = ChannelController::new(&cfg);
        let mut st = Stats::new();
        // Track per-(bank,subarray) ACT times to re-check tRC externally.
        let mut last_act = std::collections::HashMap::new();
        let mut last_cycle = -1i64;
        for _ in 0..g.usize_in(5, 40) {
            let su = g.usize_in(0, 7);
            let bank = g.usize_in(0, 3);
            let target = CmdTarget::Bank(bank);
            let open = c.banks[bank].subarrays[su].open_row.is_some();
            let at = if !open {
                let row = g.usize_in(0, 511);
                let at = c
                    .issue(
                        DramCmd::Act {
                            target,
                            subarray: su,
                            row,
                        },
                        &mut st,
                    )
                    .unwrap();
                if let Some(prev) = last_act.insert((bank, su), at) {
                    assert!(
                        at - prev >= t.t_rc as i64,
                        "tRC violated: {} then {}",
                        prev,
                        at
                    );
                }
                at
            } else if g.bool() {
                c.issue(
                    DramCmd::Rd {
                        target,
                        subarray: su,
                        col: g.usize_in(0, 31),
                    },
                    &mut st,
                )
                .unwrap()
            } else {
                c.issue(DramCmd::Pre { target, subarray: su }, &mut st)
                    .unwrap()
            };
            assert!(at > last_cycle, "command bus collision");
            last_cycle = at;
        }
    });
}

#[test]
fn act_to_column_always_waits_trcd() {
    let cfg = SimConfig::paper();
    forall(80, |g| {
        let mut c = ChannelController::new(&cfg);
        let mut st = Stats::new();
        // Random warm-up traffic on other subarrays.
        for i in 0..g.usize_in(0, 5) {
            let su = 10 + i;
            c.issue(
                DramCmd::Act {
                    target: CmdTarget::AllBanks,
                    subarray: su,
                    row: i,
                },
                &mut st,
            )
            .unwrap();
        }
        let act_at = c
            .issue(
                DramCmd::Act {
                    target: CmdTarget::AllBanks,
                    subarray: 0,
                    row: 1,
                },
                &mut st,
            )
            .unwrap();
        let rd_at = c
            .issue(
                DramCmd::Rd {
                    target: CmdTarget::AllBanks,
                    subarray: 0,
                    col: 0,
                },
                &mut st,
            )
            .unwrap();
        assert!(rd_at - act_at >= cfg.timing.t_rcd as i64);
    });
}
