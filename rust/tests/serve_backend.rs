//! Execution-backend invariants across the serving stack: chunked
//! prefill conservation, heterogeneous composition, and deterministic
//! routing over mixed backend types.

use sal_pim::config::SimConfig;
use sal_pim::serve::backend::HeteroBackend;
use sal_pim::serve::fabric::FabricParams;
use sal_pim::serve::workload::{requests_from_items, ArrivalPattern};
use sal_pim::serve::{
    BackendKind, Cluster, DeviceEngine, ExecutionBackend, GpuBackend, Request, Routing,
    SalPimBackend, ServeMetrics, SloClass,
};
use sal_pim::testutil::RequestMix;

fn req(id: u64, prompt: usize, out: usize, at: f64) -> Request {
    Request {
        id,
        prompt_len: prompt,
        max_new_tokens: out,
        arrival_s: at,
        session: id,
        slo: SloClass::Batch,
        prefix: Vec::new(),
    }
}

/// One long-prompt request followed by a decode-heavy tail — the
/// workload where inline prefill hurts most.
fn decode_heavy_mix() -> Vec<Request> {
    let mut reqs = vec![req(0, 384, 4, 0.0)];
    for i in 1..7u64 {
        reqs.push(req(i, 16, 64, 0.0));
    }
    reqs
}

#[test]
fn chunked_prefill_conserves_simulated_tokens() {
    // Chunking reorders time, never tokens: every request must simulate
    // exactly the tokens the inline-prefill engine simulates.
    let cfg = SimConfig::paper();
    let run = |chunk: Option<usize>| -> Vec<(u64, usize, usize)> {
        let mut eng = DeviceEngine::new(&cfg, 8).with_prefill_chunk(chunk);
        for r in decode_heavy_mix() {
            eng.submit(r);
        }
        let mut out: Vec<(u64, usize, usize)> = eng
            .run()
            .iter()
            .map(|c| (c.id, c.tokens_out, c.tokens_simulated))
            .collect();
        out.sort();
        out
    };
    let inline = run(None);
    assert_eq!(inline.len(), 7);
    assert_eq!(inline, run(Some(32)));
    assert_eq!(inline, run(Some(25)), "ragged chunk sizes too");
    assert_eq!(inline, run(Some(1024)), "chunk larger than any prompt");
}

#[test]
fn chunked_prefill_improves_ttft_on_a_decode_heavy_mix() {
    // Inline prefill makes the decode-heavy tail wait for the long
    // prompt's whole summarization before their first tokens; chunking
    // interleaves it, so mean TTFT must strictly improve.
    let cfg = SimConfig::paper();
    let run = |chunk: Option<usize>| -> (ServeMetrics, f64) {
        let mut eng = DeviceEngine::new(&cfg, 8).with_prefill_chunk(chunk);
        for r in decode_heavy_mix() {
            eng.submit(r);
        }
        let done = eng.run();
        let mean_ttft = done.iter().map(|c| c.ttft_s()).sum::<f64>() / done.len() as f64;
        (ServeMetrics::from_completions(&done), mean_ttft)
    };
    let (inline_m, inline_ttft) = run(None);
    let (chunked_m, chunked_ttft) = run(Some(32));
    assert_eq!(inline_m.total_tokens, chunked_m.total_tokens, "token conservation");
    assert!(
        chunked_ttft < inline_ttft,
        "chunked mean TTFT {chunked_ttft} !< inline {inline_ttft}"
    );
    // The decode-heavy tail no longer waits behind the whole long
    // prefill, so the median first token lands much earlier. (The long
    // request itself may finish its own prefill later — its chunks
    // interleave with everyone's decode steps — which is the trade.)
    assert!(
        chunked_m.p50_ttft_s < inline_m.p50_ttft_s,
        "chunked p50 TTFT {} !< inline {}",
        chunked_m.p50_ttft_s,
        inline_m.p50_ttft_s
    );
}

#[test]
fn ttft_is_never_double_counted_under_chunk_interleaved_prefill() {
    // Regression guard for the TTFT accounting: `ttft_s = queue_s +
    // prefill_s` must equal `decode_start - arrival` — queue covers
    // [arrival, admit], prefill covers [admit, first token], and a
    // request admitted mid-step (it arrived while another request's
    // decode step was running and joined at the next token boundary)
    // must charge that partial step to its *queue*, never to both queue
    // and prefill.
    let cfg = SimConfig::paper();

    // Single-request reference trace: chunked prefill telescopes to the
    // backend's prefill service time exactly, and matches inline.
    let single = |chunk: Option<usize>| {
        let mut eng = DeviceEngine::new(&cfg, 4).with_prefill_chunk(chunk);
        eng.submit(req(0, 96, 4, 0.0));
        eng.run().remove(0)
    };
    let inline = single(None);
    let chunked = single(Some(32));
    let mut backend = SalPimBackend::new(&cfg);
    let service = backend.prefill_s(96);
    for (label, c) in [("inline", &inline), ("chunked", &chunked)] {
        assert_eq!(c.queue_s, 0.0, "{label}: lone request never queues");
        assert!(
            (c.ttft_s() - service).abs() < 1e-12 + 1e-9 * service,
            "{label}: ttft {} != prefill service {service}",
            c.ttft_s()
        );
    }

    // Mid-step admission: request 1 arrives while request 0's first
    // chunks/steps are in flight, so it waits for a token boundary.
    let mut eng = DeviceEngine::new(&cfg, 4).with_prefill_chunk(Some(16));
    eng.submit(req(0, 96, 16, 0.0));
    eng.submit(req(1, 48, 8, 1e-6)); // mid-step arrival
    let done = eng.run();
    assert_eq!(done.len(), 2);
    for c in &done {
        let arrival = if c.id == 0 { 0.0 } else { 1e-6 };
        let span = c.finish_s - arrival;
        let parts = c.queue_s + c.prefill_s + c.decode_s;
        // The three spans tile [arrival, finish] with no overlap — a
        // double-counted TTFT would make `parts` exceed `span`.
        assert!(
            (parts - span).abs() < 1e-12 + 1e-9 * span,
            "request {}: queue+prefill+decode {parts} != finish-arrival {span}",
            c.id
        );
        assert!(
            (c.ttft_s() - (span - c.decode_s)).abs() < 1e-12 + 1e-9 * span,
            "request {}: ttft must be finish - arrival - decode",
            c.id
        );
        assert!(c.queue_s >= 0.0 && c.prefill_s >= 0.0 && c.decode_s >= 0.0);
    }
    let late = done.iter().find(|c| c.id == 1).unwrap();
    assert!(
        late.queue_s > 0.0,
        "mid-step arrival must wait for the token boundary in queue_s"
    );
    let mut backend = SalPimBackend::new(&cfg);
    assert!(
        late.prefill_s >= backend.prefill_s(48) - 1e-12,
        "interleaving can only lengthen the admission-to-first-token span"
    );
}

#[test]
fn hetero_backend_is_gpu_prefill_plus_pim_decode_plus_handoff() {
    let cfg = SimConfig::paper();
    let mut het = HeteroBackend::gpu_prefill_pim_decode(&cfg);
    let mut gpu = GpuBackend::titan_rtx(&cfg.model);
    let mut pim = SalPimBackend::new(&cfg);

    for n in [16usize, 64, 128] {
        let handoff = FabricParams::pcie().transfer_s(n * cfg.model.kv_bytes_per_token());
        let want = gpu.prefill_s(n) + handoff;
        let got = het.prefill_s(n);
        assert!(
            (got - want).abs() < 1e-15 + 1e-12 * want,
            "prefill({n}): {got} != {want}"
        );
    }
    for kvs in [vec![32usize], vec![64, 96, 128]] {
        assert_eq!(
            het.decode_step_s(&kvs),
            pim.decode_step_s(&kvs),
            "decode must run on the PIM cost model"
        );
    }
    // Admission is gated by the decode device's KV region.
    assert_eq!(het.capacity().kv_total_units, pim.capacity().kv_total_units);
}

#[test]
fn mixed_backend_cluster_routes_deterministically() {
    // A cluster mixing SAL-PIM, GPU and hetero devices must replay
    // assignments and timings exactly under a fixed workload seed.
    let cfg = SimConfig::paper();
    let items = RequestMix::small(21).take(24);
    for routing in [Routing::RoundRobin, Routing::LeastLoaded, Routing::SessionAffinity] {
        let run = || {
            let engines = vec![
                DeviceEngine::with_backend(BackendKind::SalPim.build(&cfg), 4),
                DeviceEngine::with_backend(BackendKind::Gpu.build(&cfg), 4),
                DeviceEngine::with_backend(BackendKind::Hetero.build(&cfg), 4),
            ];
            let mut c = Cluster::from_engines(engines, routing);
            let arrivals = ArrivalPattern::Poisson { rate_rps: 500.0 };
            for r in requests_from_items(&items, arrivals, 6) {
                c.submit(r);
            }
            let done = c.run();
            let finishes: Vec<(u64, u64)> = done
                .iter()
                .map(|c| (c.id, (c.finish_s * 1e12) as u64))
                .collect();
            (c.assignments().to_vec(), finishes)
        };
        let (a1, f1) = run();
        let (a2, f2) = run();
        assert_eq!(a1, a2, "{}: assignment drift", routing.name());
        assert_eq!(f1, f2, "{}: timing drift", routing.name());
        assert_eq!(f1.len(), 24, "{}: everything served", routing.name());
    }
}

#[test]
fn every_backend_serves_the_same_mix_to_completion() {
    // The trait contract end-to-end: each backend family drains the
    // identical queue with no rejects and conserves the token budget.
    let cfg = SimConfig::paper();
    let items = RequestMix::small(5).take(10);
    let budget: usize = items.iter().map(|it| it.max_new_tokens).sum();
    for kind in BackendKind::ALL {
        let mut eng = DeviceEngine::with_backend(kind.build(&cfg), 4);
        for r in requests_from_items(&items, ArrivalPattern::AtOnce, 4) {
            eng.submit(r);
        }
        let m = ServeMetrics::from_completions(&eng.run());
        assert_eq!(m.requests, 10, "{}", kind.name());
        assert_eq!(m.total_tokens, budget, "{}", kind.name());
        assert_eq!(eng.report().rejected, 0, "{}", kind.name());
    }
}
