//! Randomized equivalence between the two run-loop cores.
//!
//! The discrete-event core (`EngineCore::Event`) reorganizes *when* the
//! engine looks at scheduling work — heap-ordered completion tracking,
//! memoized admission, skipped no-op phases — but must never change
//! *what* happens at any token boundary. These properties pin that down:
//! over random request mixes × backends × KV policies × prefill-chunk
//! settings, the event core must produce bit-identical completions,
//! identical reports and an identical trace-event stream to the legacy
//! token-boundary scan (`--engine-core legacy`).

use sal_pim::config::SimConfig;
use sal_pim::serve::workload::{requests_from_items, ArrivalPattern};
use sal_pim::serve::{
    BackendKind, Cluster, Completion, DeviceEngine, EngineCore, EngineReport, EvictPolicy,
    KvPolicy, Policy, Request, Routing,
};
use sal_pim::testutil::{forall, Gen, RequestMix};
use sal_pim::trace::TraceHandle;

/// One randomly drawn engine configuration plus its workload.
struct Case {
    backend: BackendKind,
    policy: Policy,
    kv_policy: KvPolicy,
    evict: EvictPolicy,
    chunk: Option<usize>,
    max_batch: usize,
    kv_units: Option<usize>,
    requests: Vec<Request>,
}

fn draw_case(g: &mut Gen) -> Case {
    let backend = *g.choose(&BackendKind::ALL);
    let policy = *g.choose(&[
        Policy::Fcfs,
        Policy::ShortestJobFirst,
        Policy::ShortestPromptFirst,
    ]);
    let kv_policy = *g.choose(&[KvPolicy::Whole, KvPolicy::Paged]);
    let evict = *g.choose(&[EvictPolicy::Lru, EvictPolicy::None]);
    let chunk = if g.bool() {
        Some(g.usize_in(1, 16))
    } else {
        None
    };
    let max_batch = g.usize_in(1, 6);
    // Sometimes squeeze the KV region to force admission stalls,
    // evictions and (under paged + lru) preemptions.
    let kv_units = if g.bool() {
        Some(g.usize_in(8, 64))
    } else {
        None
    };
    let n_req = g.usize_in(1, 12);
    let n_sessions = g.usize_in(1, 4);
    let items = RequestMix::small(g.u64_in(0, 1 << 20)).take(n_req);
    let pattern = if g.bool() {
        ArrivalPattern::AtOnce
    } else {
        ArrivalPattern::Poisson {
            rate_rps: g.f64_in(5.0, 500.0),
        }
    };
    Case {
        backend,
        policy,
        kv_policy,
        evict,
        chunk,
        max_batch,
        kv_units,
        requests: requests_from_items(&items, pattern, n_sessions),
    }
}

fn build_engine(cfg: &SimConfig, case: &Case, core: EngineCore) -> DeviceEngine {
    let mut e = DeviceEngine::with_backend(case.backend.build(cfg), case.max_batch)
        .with_core(core)
        .with_policy(case.policy)
        .with_kv_policy(case.kv_policy)
        .with_evict(case.evict)
        .with_prefill_chunk(case.chunk);
    if let Some(units) = case.kv_units {
        e = e.with_kv_subarrays(units);
    }
    e
}

/// Compare two runs field by field; float fields are compared as raw
/// bits, so equality means *bit* equality, not approximate agreement.
/// The wall-clock self-profile is excluded (host timing, inherently
/// nondeterministic); everything else in the report must match.
fn assert_runs_identical(
    label: &str,
    ev_done: &[Completion],
    lg_done: &[Completion],
    ev_rep: &EngineReport,
    lg_rep: &EngineReport,
) {
    assert_eq!(ev_done.len(), lg_done.len(), "{label}: completion count");
    for (e, l) in ev_done.iter().zip(lg_done) {
        assert_eq!(
            (e.id, e.prompt_len, e.tokens_out, e.tokens_simulated, e.device),
            (l.id, l.prompt_len, l.tokens_out, l.tokens_simulated, l.device),
            "{label}: completion fields"
        );
        for (name, a, b) in [
            ("queue_s", e.queue_s, l.queue_s),
            ("prefill_s", e.prefill_s, l.prefill_s),
            ("decode_s", e.decode_s, l.decode_s),
            ("finish_s", e.finish_s, l.finish_s),
        ] {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{label}: {name} diverged on id={} ({a} vs {b})",
                e.id
            );
        }
    }
    assert_eq!(ev_rep.rejected, lg_rep.rejected, "{label}: rejected");
    assert_eq!(
        ev_rep.kv_peak_utilization.to_bits(),
        lg_rep.kv_peak_utilization.to_bits(),
        "{label}: kv_peak_utilization"
    );
    assert_eq!(ev_rep.max_batch_seen, lg_rep.max_batch_seen, "{label}: max_batch_seen");
    assert_eq!(ev_rep.decode_steps, lg_rep.decode_steps, "{label}: decode_steps");
    assert_eq!(
        ev_rep.mean_decode_batch.to_bits(),
        lg_rep.mean_decode_batch.to_bits(),
        "{label}: mean_decode_batch"
    );
    assert_eq!(ev_rep.preemptions, lg_rep.preemptions, "{label}: preemptions");
    assert_eq!(ev_rep.recompute_tokens, lg_rep.recompute_tokens, "{label}: recompute_tokens");
    assert_eq!(ev_rep.reuse_hits, lg_rep.reuse_hits, "{label}: reuse_hits");
    assert_eq!(ev_rep.reuse_tokens, lg_rep.reuse_tokens, "{label}: reuse_tokens");
    assert_eq!(ev_rep.prefix_hits, lg_rep.prefix_hits, "{label}: prefix_hits");
    assert_eq!(
        ev_rep.prefix_reused_tokens, lg_rep.prefix_reused_tokens,
        "{label}: prefix_reused_tokens"
    );
    assert_eq!(ev_rep.truncated, lg_rep.truncated, "{label}: truncated");
}

#[test]
fn event_core_is_bit_identical_on_random_single_device_runs() {
    let cfg = SimConfig::paper();
    forall(40, |g| {
        let case = draw_case(g);
        let label = format!(
            "backend={} policy={:?} kv={:?}/{:?} chunk={:?} batch={} units={:?} n={}",
            case.backend.name(),
            case.policy,
            case.kv_policy,
            case.evict,
            case.chunk,
            case.max_batch,
            case.kv_units,
            case.requests.len()
        );

        let mut ev = build_engine(&cfg, &case, EngineCore::Event);
        let mut lg = build_engine(&cfg, &case, EngineCore::Legacy);
        let ev_trace = TraceHandle::new();
        let lg_trace = TraceHandle::new();
        ev.set_trace(ev_trace.clone());
        lg.set_trace(lg_trace.clone());
        for r in &case.requests {
            ev.submit(r.clone());
            lg.submit(r.clone());
        }

        let ev_done = ev.run();
        let lg_done = lg.run();
        assert_runs_identical(&label, &ev_done, &lg_done, &ev.report(), &lg.report());
        let ev_rejected: Vec<u64> = ev.rejected().iter().map(|r| r.id).collect();
        let lg_rejected: Vec<u64> = lg.rejected().iter().map(|r| r.id).collect();
        assert_eq!(ev_rejected, lg_rejected, "{label}: rejected requests");
        // The full lifecycle stream — arrivals, admissions, prefill
        // chunks, decode steps, preemptions, evictions, reuse hits,
        // completions — must match event for event.
        assert_eq!(ev_trace.take_events(), lg_trace.take_events(), "{label}: trace streams");
    });
}

#[test]
fn static_schedule_specs_are_core_invariant_and_match_legacy_flags() {
    // The typed `static:<b>` schedule must hit exactly the code path the
    // legacy `--backend <b>` flag takes, on BOTH run-loop cores — four
    // backends × two cores, every metric bit-identical.
    use sal_pim::scenario::{ConfigSel, EngineKind, Runner, Scenario, ServeParams};
    use sal_pim::serve::SchedSpec;
    for backend in BackendKind::ALL {
        for core in [EngineCore::Event, EngineCore::Legacy] {
            let base = ServeParams::default()
                .with_config(ConfigSel::preset("mini"))
                .with_engine(EngineKind::Batch)
                .with_engine_core(core)
                .with_workload(8, 17)
                .with_at_once(true);
            let legacy = base.clone().with_backend(backend);
            let spec = base.with_schedule(
                SchedSpec::parse(&format!("static:{}", backend.name())).unwrap(),
            );
            let a = Runner::new().run(&Scenario::Serve(legacy)).unwrap();
            let b = Runner::new().run(&Scenario::Serve(spec)).unwrap();
            assert_eq!(
                a.metrics,
                b.metrics,
                "backend={} core={core:?}",
                backend.name()
            );
        }
    }
}

#[test]
fn event_core_is_bit_identical_on_random_cluster_runs() {
    let cfg = SimConfig::paper();
    forall(16, |g| {
        let backend = *g.choose(&BackendKind::ALL);
        let routing = *g.choose(&[
            Routing::RoundRobin,
            Routing::LeastLoaded,
            Routing::SessionAffinity,
        ]);
        let n_devices = g.usize_in(1, 3);
        let max_batch = g.usize_in(2, 6);
        let chunk = if g.bool() {
            Some(g.usize_in(2, 8))
        } else {
            None
        };
        let units = g.usize_in(16, 48);
        let n_req = g.usize_in(4, 16);
        let n_sessions = g.usize_in(1, 6);
        let items = RequestMix::small(g.u64_in(0, 1 << 20)).take(n_req);
        let requests = requests_from_items(
            &items,
            ArrivalPattern::Poisson { rate_rps: 200.0 },
            n_sessions,
        );
        let label = format!(
            "backend={} routing={routing:?} devices={n_devices} batch={max_batch} chunk={chunk:?} units={units} n={n_req}",
            backend.name()
        );

        let build = |core: EngineCore| {
            Cluster::homogeneous(&cfg, backend, n_devices, max_batch, routing)
                .with_core(core)
                .with_kv(
                    KvPolicy::Paged,
                    EvictPolicy::Lru,
                    sal_pim::serve::PrefixCacheMode::Session,
                    None,
                    Some(units),
                )
                .with_prefill_chunk(chunk)
        };
        let mut ev = build(EngineCore::Event);
        let mut lg = build(EngineCore::Legacy);
        let ev_trace = TraceHandle::new();
        let lg_trace = TraceHandle::new();
        ev.set_trace(ev_trace.clone());
        lg.set_trace(lg_trace.clone());
        for r in &requests {
            ev.submit(r.clone());
            lg.submit(r.clone());
        }

        let ev_done = ev.run();
        let lg_done = lg.run();
        assert_eq!(ev.assignments(), lg.assignments(), "{label}: routing decisions");
        let (ev_reps, lg_reps) = (ev.per_device_reports(), lg.per_device_reports());
        assert_eq!(ev_reps.len(), lg_reps.len());
        for (d, (er, lr)) in ev_reps.iter().zip(&lg_reps).enumerate() {
            // Per-device completions, sliced out of the merged stream.
            let ef: Vec<_> = ev_done.iter().filter(|c| c.device == d).cloned().collect();
            let lf: Vec<_> = lg_done.iter().filter(|c| c.device == d).cloned().collect();
            assert_runs_identical(&format!("{label} device={d}"), &ef, &lf, er, lr);
        }
        assert_eq!(ev_trace.take_events(), lg_trace.take_events(), "{label}: trace streams");
    });
}
