//! Cross-module integration tests: mapper → engine → stats invariants,
//! functional-vs-float fidelity, LUT artifact parity with the python
//! build path, and coordinator conservation properties.

use sal_pim::config::SimConfig;
use sal_pim::coordinator::{Coordinator, Policy};
use sal_pim::interp::{LutTable, NonLinFn};
use sal_pim::mapper::GenerationSim;
use sal_pim::model::fixedpoint::{Q2_13, Q8_8};
use sal_pim::model::gpt2;
use sal_pim::stats::Phase;
use sal_pim::testutil::forall;

#[test]
fn decode_traffic_conservation() {
    // Internal bytes measured by the engine must be ≥ the model's weight
    // bytes (per-pch share) for every KV length — nothing is skipped.
    let cfg = SimConfig::paper();
    let mut sim = GenerationSim::new(&cfg);
    for kv in [1usize, 64, 512, 1000] {
        let st = sim.decode_token(kv);
        let device_bytes = st.internal_bytes * cfg.hbm.pseudo_channels() as u64;
        let weight_bytes = gpt2::decode_weight_bytes(&cfg.model, kv) as u64;
        assert!(
            device_bytes >= weight_bytes,
            "kv={kv}: device {device_bytes} < weights {weight_bytes}"
        );
        assert!(
            device_bytes < weight_bytes * 2,
            "kv={kv}: device reads {device_bytes} ≫ weights {weight_bytes}"
        );
    }
}

#[test]
fn decode_cycles_monotone_in_kv_and_psub() {
    let mut sims: Vec<GenerationSim> = [1usize, 2, 4]
        .iter()
        .map(|&p| GenerationSim::new(&SimConfig::paper().with_p_sub(p)))
        .collect();
    let mut prev_by_p = [u64::MAX; 3];
    for kv in [8usize, 64, 256, 768] {
        let mut prev_kv = 0;
        for (i, sim) in sims.iter_mut().enumerate() {
            let c = sim.decode_token(kv).cycles;
            // More parallelism is never slower.
            assert!(c <= prev_by_p[i.min(2)] || kv > 8, "psub order broken");
            if i > 0 {
                assert!(c <= prev_kv, "P_Sub={} slower than P_Sub smaller", 1 << i);
            }
            prev_kv = c;
            if kv == 8 {
                prev_by_p[i] = c;
            }
        }
    }
}

#[test]
fn random_model_shapes_simulate_cleanly() {
    // Fuzz the mapper+engine over random transformer shapes: no panics,
    // no timing violations, sane traffic.
    forall(25, |g| {
        let mut cfg = SimConfig::paper();
        cfg.model.d_model = 64 * g.usize_in(1, 32); // 64..2048
        cfg.model.n_heads = [4usize, 8, 16][g.usize_in(0, 2)];
        while cfg.model.d_model % cfg.model.n_heads != 0 {
            cfg.model.n_heads /= 2;
        }
        cfg.model.d_ff = cfg.model.d_model * 4;
        cfg.model.n_layers = g.usize_in(1, 6);
        cfg.model.vocab = 1024;
        let kv = g.usize_in(1, 256);
        let mut sim = GenerationSim::new(&cfg);
        let st = sim.decode_token(kv);
        assert!(st.cycles > 0);
        assert!(st.internal_bytes > 0);
        let sum: u64 = st.phase_cycles.values().sum();
        assert_eq!(sum, st.cycles, "phase attribution leak");
    });
}

#[test]
fn lut_artifact_parity_with_python() {
    // `make artifacts` writes the python-generated tables; the rust
    // tables must be bit-identical (shared spec, both sides).
    let dir = sal_pim::runtime::default_artifacts_dir().join("luts");
    if !dir.exists() {
        eprintln!("SKIP: lut artifacts not built");
        return;
    }
    for f in NonLinFn::ALL {
        let path = dir.join(format!("{}_64.txt", f.name()));
        let text = std::fs::read_to_string(&path).expect("lut artifact");
        let q_out = match f {
            NonLinFn::Exp | NonLinFn::Recip => Q2_13,
            _ => Q8_8,
        };
        let table = LutTable::build(f, 64, Q8_8, q_out);
        assert_eq!(
            text,
            table.to_artifact_text(),
            "python vs rust LUT mismatch for {}",
            f.name()
        );
    }
}

#[test]
fn coordinator_conserves_and_orders_time() {
    let cfg = SimConfig::paper();
    forall(10, |g| {
        let mut coord = Coordinator::new(&cfg).with_policy(Policy::Fcfs);
        let n = g.usize_in(1, 8);
        let mut arrival = 0.0;
        for _ in 0..n {
            arrival += g.f64_in(0.0, 0.2);
            coord.submit(16 * g.usize_in(1, 8), 1 << g.usize_in(0, 6), arrival);
        }
        let done = coord.run();
        assert_eq!(done.len(), n);
        // Device never runs two requests at once: finishes are ordered
        // and gaps between service intervals are non-negative.
        let mut last_finish = 0.0f64;
        for c in &done {
            let start = c.finish_s - c.prefill_s - c.decode_s;
            assert!(start + 1e-12 >= last_finish, "overlapping service");
            assert!(c.queue_s >= 0.0 && c.prefill_s > 0.0);
            last_finish = c.finish_s;
        }
    });
}

#[test]
fn prefill_plus_decode_equals_generation() {
    // GenerationSim must compose exactly from its parts.
    let cfg = SimConfig::paper();
    let mut sim = GenerationSim::new(&cfg);
    let r = sim.generate(32, 16);
    let prefill = sim.prefill(32);
    let decode_sum: u64 = (1..16).map(|i| sim.decode_token(32 + i).cycles).sum();
    assert_eq!(r.prefill.cycles, prefill.cycles);
    assert_eq!(r.decode.cycles, decode_sum);
}

#[test]
fn breakdown_has_expected_structure() {
    // §6.2: matrix ops ≈ 60 % of decode; nonlinear visible but minor
    // after LUT acceleration; data movement non-trivial (C-ALU merges).
    let cfg = SimConfig::paper();
    let mut sim = GenerationSim::new(&cfg);
    let st = sim.decode_token(256);
    let matrix = st.phase_fraction(Phase::Mha)
        + st.phase_fraction(Phase::Ffn)
        + st.phase_fraction(Phase::LmHead);
    let nl = st.phase_fraction(Phase::NonLinear);
    let dm = st.phase_fraction(Phase::DataMovement);
    assert!(matrix > 0.40 && matrix < 0.85, "matrix {matrix}");
    assert!(nl > 0.01 && nl < 0.30, "nonlinear {nl}");
    assert!(dm > 0.05 && dm < 0.45, "data movement {dm}");
}
