//! Cluster serving engine: cross-module invariants and the acceptance
//! bars for continuous batching + multi-device scaling.

use sal_pim::config::SimConfig;
use sal_pim::coordinator::Coordinator;
use sal_pim::serve::workload::{self, requests_from_items, ArrivalPattern};
use sal_pim::serve::{Cluster, DeviceEngine, KvCacheManager, Routing, ServeMetrics};
use sal_pim::testutil::{forall, RequestMix};
use std::collections::HashMap;

#[test]
fn kv_manager_never_over_admits() {
    // Property: over random admit/release mixes, the reserved subarray
    // count exactly tracks the ledger and never exceeds the region.
    let cfg = SimConfig::paper();
    forall(50, |g| {
        let total = g.usize_in(1, 64);
        let mut kv = KvCacheManager::with_kv_subarrays(&cfg, total);
        let mut leases = Vec::new();
        let mut ledger = 0usize;
        for _ in 0..g.usize_in(1, 40) {
            if g.bool() || leases.is_empty() {
                let tokens = g.usize_in(1, 400);
                let need = kv.subarrays_for(tokens);
                match kv.try_admit(0, tokens) {
                    Some(lease) => {
                        ledger += need;
                        leases.push(lease);
                    }
                    None => {
                        assert!(
                            need > total - ledger,
                            "refused a request that fit: need {need}, free {}",
                            total - ledger
                        );
                    }
                }
            } else {
                let i = g.usize_in(0, leases.len() - 1);
                let lease = leases.swap_remove(i);
                ledger -= lease.subarrays;
                kv.release(lease);
            }
            assert!(kv.used_subarrays() <= kv.total_subarrays(), "over-admitted");
            assert_eq!(kv.used_subarrays(), ledger, "ledger drift");
            assert!(kv.utilization() <= 1.0 + 1e-12);
        }
    });
}

#[test]
fn continuous_batching_preserves_token_counts() {
    // Batching reorders *time*, never output budgets: every request
    // produces exactly the tokens the sequential path produces.
    let cfg = SimConfig::paper();
    let items = RequestMix::small(11).take(10);
    let reqs = requests_from_items(&items, ArrivalPattern::Jittered { scale_s: 0.01 }, 4);

    // Compare the *simulated* counts (prefill token + executed decode
    // iterations), not the echoed budget — a scheduler bug that dropped
    // or duplicated decode steps must fail this.
    let counts = |done: Vec<sal_pim::serve::Completion>| -> HashMap<u64, (usize, usize)> {
        done.iter()
            .map(|c| (c.id, (c.tokens_out, c.tokens_simulated)))
            .collect()
    };

    let mut coord = Coordinator::new(&cfg);
    for r in reqs.clone() {
        coord.submit_request(r);
    }
    let seq = counts(coord.run());

    let mut eng = DeviceEngine::new(&cfg, 4);
    for r in reqs {
        eng.submit(r);
    }
    let bat = counts(eng.run());

    assert_eq!(seq.len(), 10);
    for (budget, simulated) in seq.values() {
        assert!(*simulated >= 1 && *simulated <= (*budget).max(1));
    }
    assert_eq!(seq, bat, "per-request token counts must match");
}

#[test]
fn routing_is_deterministic_under_a_fixed_seed() {
    let cfg = SimConfig::paper();
    let reqs = || workload::generate_small(21, 24, ArrivalPattern::Poisson { rate_rps: 500.0 }, 6);
    for routing in [Routing::RoundRobin, Routing::LeastLoaded, Routing::SessionAffinity] {
        let run = || {
            let mut c = Cluster::new(&cfg, 3, 4, routing);
            for r in reqs() {
                c.submit(r);
            }
            let done = c.run();
            let finishes: Vec<(u64, u64)> = done
                .iter()
                .map(|c| (c.id, (c.finish_s * 1e12) as u64))
                .collect();
            (c.assignments().to_vec(), finishes)
        };
        let (a1, f1) = run();
        let (a2, f2) = run();
        assert_eq!(a1, a2, "{}: assignment drift", routing.name());
        assert_eq!(f1, f2, "{}: timing drift", routing.name());
    }
}

#[test]
fn continuous_batching_beats_sequential_fcfs_on_the_16_request_mix() {
    // Acceptance: strictly higher simulated throughput (tok/s over
    // makespan) than sequential FCFS on the same 16-request mix.
    let cfg = SimConfig::paper();
    let items = RequestMix::paper(42).take(16);
    let reqs = requests_from_items(&items, ArrivalPattern::AtOnce, 8);

    let mut coord = Coordinator::new(&cfg);
    for r in reqs.clone() {
        coord.submit_request(r);
    }
    let seq = ServeMetrics::from_completions(&coord.run());

    let mut eng = DeviceEngine::new(&cfg, 8);
    for r in reqs {
        eng.submit(r);
    }
    let bat = ServeMetrics::from_completions(&eng.run());

    assert_eq!(seq.requests, 16);
    assert_eq!(bat.requests, 16);
    assert_eq!(seq.total_tokens, bat.total_tokens, "token conservation");
    assert!(
        bat.throughput_tok_s > seq.throughput_tok_s,
        "batching {} tok/s !> sequential {} tok/s",
        bat.throughput_tok_s,
        seq.throughput_tok_s
    );
}

#[test]
fn four_device_cluster_scales_at_saturating_load() {
    // Acceptance: ≥ 2.5× throughput over one device at saturating load
    // (everything queued at t = 0, more work than one device's batch).
    let cfg = SimConfig::paper();
    let items = RequestMix::small(7).take(48);
    let reqs = requests_from_items(&items, ArrivalPattern::AtOnce, 8);

    let run = |devices: usize| {
        let mut c = Cluster::new(&cfg, devices, 8, Routing::RoundRobin);
        for r in reqs.clone() {
            c.submit(r);
        }
        ServeMetrics::from_completions(&c.run())
    };
    let one = run(1);
    let four = run(4);
    assert_eq!(one.total_tokens, four.total_tokens, "token conservation");
    let speedup = four.throughput_tok_s / one.throughput_tok_s;
    assert!(
        speedup >= 2.5,
        "4-device speedup {speedup:.2}× < 2.5× (one {} tok/s, four {} tok/s)",
        one.throughput_tok_s,
        four.throughput_tok_s
    );
}

#[test]
fn kv_exhaustion_throttles_but_serves_everything() {
    // With a KV region sized for ~2 concurrent windows, the engine must
    // serialize admissions yet still serve the whole queue.
    let cfg = SimConfig::paper();
    // Uniform windows make the arithmetic exact: each request pins
    // ceil(48 tokens / tokens-per-subarray) subarrays; the region holds
    // exactly two such windows.
    let window_subs = {
        let kv = KvCacheManager::with_kv_subarrays(&cfg, 1);
        kv.subarrays_for(32 + 16)
    };
    let mut eng = DeviceEngine::new(&cfg, 8).with_kv_subarrays(2 * window_subs);
    for i in 0..8u64 {
        eng.submit(sal_pim::serve::Request {
            id: i,
            prompt_len: 32,
            max_new_tokens: 16,
            arrival_s: 0.0,
            session: i,
            slo: sal_pim::serve::SloClass::Batch,
            prefix: Vec::new(),
        });
    }
    let done = eng.run();
    assert_eq!(done.len(), 8, "all requests served");
    let rep = eng.report();
    assert_eq!(rep.rejected, 0);
    assert!(rep.max_batch_seen <= 2, "KV cap must bound concurrency");
    assert!(rep.kv_peak_utilization > 0.5);
}
