//! Disaggregated prefill/decode serving invariants: an ideal fabric
//! reproduces the single-pool hetero run bit-for-bit, migration and
//! swap-to-host never create or destroy tokens, link contention is
//! monotone in concurrency, and readmission picks the cheaper of
//! swap-in and recompute.

use sal_pim::config::SimConfig;
use sal_pim::serve::backend::HeteroBackend;
use sal_pim::serve::workload::{requests_from_items, ArrivalPattern};
use sal_pim::serve::{
    BackendKind, Cluster, DeviceEngine, DisaggregatedCluster, EvictPolicy, Fabric,
    FabricParams, GpuBackend, KvPolicy, PrefixCacheMode, Request, Routing, SalPimBackend,
    SloClass,
};
use sal_pim::testutil::RequestMix;

fn req(id: u64, prompt: usize, out: usize, at: f64) -> Request {
    Request {
        id,
        prompt_len: prompt,
        max_new_tokens: out,
        arrival_s: at,
        session: id,
        slo: SloClass::Batch,
        prefix: Vec::new(),
    }
}

/// Subarrays one `tokens`-wide window pins on a SAL-PIM device.
fn subarrays_for(cfg: &SimConfig, tokens: usize) -> usize {
    (tokens * cfg.model.kv_bytes_per_token()).div_ceil(cfg.hbm.subarray_bytes())
}

#[test]
fn ideal_fabric_reproduces_the_single_pool_hetero_run_bit_for_bit() {
    // Zero-latency, infinite-bandwidth migration makes the two-pool
    // topology indistinguishable from one hetero device: GPU prefill,
    // zero-cost KV movement, SAL-PIM decode. Arrivals are spaced past
    // each request's service time so batching can't diverge, and every
    // float in every completion must match bit-for-bit.
    let cfg = SimConfig::paper();
    let shapes = [(16usize, 8usize), (48, 16), (96, 4), (32, 32), (64, 8)];
    let submit_all = |f: &mut dyn FnMut(Request)| {
        for (i, &(prompt, out)) in shapes.iter().enumerate() {
            f(req(i as u64, prompt, out, i as f64));
        }
    };

    let mut disagg = DisaggregatedCluster::from_pools(
        vec![DeviceEngine::with_backend(BackendKind::Gpu.build(&cfg), 8)],
        vec![DeviceEngine::with_backend(BackendKind::SalPim.build(&cfg), 8)],
        FabricParams::ideal(),
    );
    submit_all(&mut |r| {
        disagg.submit(r);
    });
    let mut two_pool = disagg.run();
    two_pool.sort_by_key(|c| c.id);

    let hetero = HeteroBackend::new(
        Box::new(GpuBackend::titan_rtx(&cfg.model)),
        Box::new(SalPimBackend::new(&cfg)),
        FabricParams::ideal(),
    );
    let mut single = Cluster::from_engines(
        vec![DeviceEngine::with_backend(Box::new(hetero), 8)],
        Routing::RoundRobin,
    );
    submit_all(&mut |r| {
        single.submit(r);
    });
    let mut one_pool = single.run();
    one_pool.sort_by_key(|c| c.id);

    assert_eq!(two_pool.len(), shapes.len());
    assert_eq!(one_pool.len(), shapes.len());
    for (a, b) in two_pool.iter().zip(&one_pool) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens_out, b.tokens_out, "req {}", a.id);
        assert_eq!(a.tokens_simulated, b.tokens_simulated, "req {}", a.id);
        assert_eq!(a.queue_s.to_bits(), b.queue_s.to_bits(), "req {} queue", a.id);
        assert_eq!(
            a.prefill_s.to_bits(),
            b.prefill_s.to_bits(),
            "req {} prefill",
            a.id
        );
        assert_eq!(
            a.decode_s.to_bits(),
            b.decode_s.to_bits(),
            "req {} decode",
            a.id
        );
        assert_eq!(
            a.finish_s.to_bits(),
            b.finish_s.to_bits(),
            "req {} finish",
            a.id
        );
    }
    // The bytes still crossed the (free) link — one migration each.
    let (bytes, transfers) = disagg.fabric_stats();
    assert_eq!(transfers, shapes.len() as u64);
    let want: u64 = shapes
        .iter()
        .map(|&(p, _)| ((p + 1) * cfg.model.kv_bytes_per_token()) as u64)
        .sum();
    assert_eq!(bytes, want);
}

#[test]
fn tokens_are_conserved_under_migration_swap_and_recompute() {
    // Randomized conservation sweep: the same drawn workload served by
    // (a) a disaggregated cluster with swap-to-host eviction, (b) the
    // same cluster with recompute-on-readmit, and (c) a single-pool
    // hetero cluster must simulate the identical per-request token
    // counts — migration and spilling move KV, never tokens.
    let cfg = SimConfig::paper();
    // Small-mix windows top out at 64 + 32 tokens; ~2 windows per
    // decode device forces preemption without ever rejecting.
    let tight = subarrays_for(&cfg, 64 + 32) * 2;
    for seed in [3u64, 11, 29] {
        let workload = || {
            let items = RequestMix::small(seed).take(18);
            requests_from_items(&items, ArrivalPattern::AtOnce, 6)
        };
        let disagg_run = |evict: EvictPolicy| {
            let mut c = DisaggregatedCluster::new(&cfg, 2, 2, 8, FabricParams::pcie())
                .with_kv(KvPolicy::Paged, evict, PrefixCacheMode::Session, None, Some(tight));
            for r in workload() {
                c.submit(r);
            }
            let mut done: Vec<(u64, usize, usize)> = c
                .run()
                .iter()
                .map(|d| (d.id, d.tokens_out, d.tokens_simulated))
                .collect();
            done.sort();
            assert_eq!(c.rejected(), 0, "seed {seed}: the region fits every window");
            let reports = c.per_device_reports();
            let (bytes, _) = c.fabric_stats();
            (done, reports, bytes)
        };
        let (swap, swap_reports, swap_bytes) = disagg_run(EvictPolicy::Swap);
        let (recompute, _, _) = disagg_run(EvictPolicy::Lru);
        assert_eq!(
            swap, recompute,
            "seed {seed}: swap-to-host changed simulated tokens"
        );

        let mut single = Cluster::homogeneous(&cfg, BackendKind::Hetero, 2, 8, Routing::LeastLoaded);
        for r in workload() {
            single.submit(r);
        }
        let mut baseline: Vec<(u64, usize, usize)> = single
            .run()
            .iter()
            .map(|d| (d.id, d.tokens_out, d.tokens_simulated))
            .collect();
        baseline.sort();
        assert_eq!(
            swap, baseline,
            "seed {seed}: disaggregation changed simulated tokens"
        );

        // The sweep is only meaningful if the machinery actually fired.
        let preemptions: usize = swap_reports.iter().map(|r| r.preemptions).sum();
        let swap_outs: usize = swap_reports.iter().map(|r| r.swap_outs).sum();
        assert!(preemptions > 0, "seed {seed}: no capacity pressure");
        assert!(swap_outs > 0, "seed {seed}: preemption must spill under swap");
        assert!(swap_bytes > 0, "seed {seed}: migrations must move bytes");
    }
}

#[test]
fn fabric_contention_is_monotone_in_concurrency() {
    // More concurrent transfers on a link never make any single
    // transfer faster — for every class with finite bandwidth, at
    // several payload sizes and background loads.
    for params in [FabricParams::pcie(), FabricParams::nvlink()] {
        for bytes in [1usize << 10, 1 << 20, 1 << 26] {
            let mut last = 0.0f64;
            for background in 0..6usize {
                let mut link = Fabric::new(params);
                for _ in 0..background {
                    link.transfer(0.0, 64 << 20);
                }
                let dt = link.peek_transfer_s(0.0, bytes);
                assert!(
                    dt >= last,
                    "{background} background transfers made a {bytes}-byte \
                     transfer faster: {dt} < {last}"
                );
                // Committing charges exactly what the probe promised.
                assert_eq!(link.transfer(0.0, bytes).to_bits(), dt.to_bits());
                last = dt;
            }
        }
    }
    // The ideal class is immune to contention by construction.
    let mut ideal = Fabric::new(FabricParams::ideal());
    for _ in 0..8 {
        assert_eq!(ideal.transfer(0.0, 1 << 30), 0.0);
    }
}

#[test]
fn readmission_picks_the_cheaper_of_swap_in_and_recompute() {
    // The same preemption-heavy workload under three link classes: with
    // recompute-only eviction nothing touches the fabric; with swap over
    // an ideal link every readmission swaps in (zero is always cheaper
    // than recompute); with swap over a 1 B/s link every readmission
    // recomputes (the spill is a sunk cost, the swap-in never wins).
    let cfg = SimConfig::paper();
    let tight = subarrays_for(&cfg, 3 * 40);
    let run = |evict: EvictPolicy, fabric: FabricParams| {
        let mut e = DeviceEngine::new(&cfg, 8)
            .with_kv_policy(KvPolicy::Paged)
            .with_evict(evict)
            .with_kv_subarrays(tight)
            .with_fabric(fabric);
        for i in 0..6 {
            e.submit(req(i, 8, 32, 0.0));
        }
        let done = e.run();
        assert_eq!(done.len(), 6);
        for c in &done {
            assert_eq!(c.tokens_simulated, 32, "request {} lost tokens", c.id);
        }
        e.report()
    };

    let lru = run(EvictPolicy::Lru, FabricParams::pcie());
    assert!(lru.preemptions > 0, "workload must force preemption");
    assert!(lru.recompute_tokens > 0);
    assert_eq!((lru.swap_outs, lru.swap_ins, lru.swapped_bytes), (0, 0, 0));

    let swap_fast = run(EvictPolicy::Swap, FabricParams::ideal());
    assert!(swap_fast.swap_outs > 0, "preemption under swap must spill");
    assert_eq!(
        swap_fast.swap_ins, swap_fast.swap_outs,
        "a free link swaps every readmission back in"
    );
    assert_eq!(swap_fast.recompute_tokens, 0);
    assert!(swap_fast.swapped_bytes > 0);

    let swap_slow = run(
        EvictPolicy::Swap,
        FabricParams {
            bandwidth_bytes_s: 1.0,
            base_latency_s: 0.0,
        },
    );
    assert!(swap_slow.swap_outs > 0);
    assert_eq!(swap_slow.swap_ins, 0, "a 1 B/s swap-in can never win");
    assert!(swap_slow.recompute_tokens > 0);
}
