//! Scenario round-trip and suite-level integration tests.
//!
//! * property-style serialize → parse equality over randomized
//!   scenarios (every kind, every optional field toggled);
//! * parse → run → serialize → parse stability for runnable scenarios;
//! * the checked-in `scenarios/smoke.toml` suite parses, runs, and its
//!   outcomes group into schema-versioned `BENCH_*.json` files;
//! * scenario outcomes agree with the direct simulator numbers, so
//!   `--json` metrics match the text tables the CLI prints.

use sal_pim::config::SimConfig;
use sal_pim::mapper::GenerationSim;
use sal_pim::scenario::{
    file::{parse_suite, suite_to_toml},
    sink, BreakdownParams, ConfigSel, EngineKind, PowerParams, Runner, Scenario, ServeParams,
    SimulateParams, SweepParams, SCHEMA_VERSION,
};
use sal_pim::serve::{BackendKind, Policy, Routing, WorkloadSpec};
use sal_pim::testutil::SplitMix64;

fn rand_config(rng: &mut SplitMix64) -> ConfigSel {
    let mut sel = if rng.below(2) == 0 {
        ConfigSel::preset("paper")
    } else {
        ConfigSel::preset("mini")
    };
    if rng.below(2) == 0 {
        sel = sel.with_p_sub([1, 2, 4][rng.below(3) as usize]);
    }
    if rng.below(3) == 0 {
        sel = sel.with_override("lut.sections", ["32", "64", "128"][rng.below(3) as usize]);
    }
    sel
}

/// A random scenario; always serializable, not necessarily runnable.
fn rand_scenario(rng: &mut SplitMix64) -> Scenario {
    let config = rand_config(rng);
    match rng.below(7) {
        0 => Scenario::Simulate(
            SimulateParams::default()
                .with_config(config)
                .with_io(1 + rng.below(64) as usize, 1 + rng.below(64) as usize)
                .with_prefetch(rng.below(2) == 0),
        ),
        1 => Scenario::Sweep(
            SweepParams::default()
                .with_config(config)
                .with_grid(
                    vec![1 + rng.below(32) as usize, 64],
                    vec![1, 1 + rng.below(128) as usize],
                ),
        ),
        2 => Scenario::Breakdown(
            BreakdownParams::default()
                .with_config(config)
                .with_kv(1 + rng.below(256) as usize),
        ),
        3 => Scenario::Power(
            PowerParams::default()
                .with_config(config)
                .with_io(1 + rng.below(32) as usize, 1 + rng.below(32) as usize)
                .with_p_subs(vec![1, [2, 4][rng.below(2) as usize]]),
        ),
        4 => Scenario::Area(sal_pim::scenario::AreaParams::default().with_config(config)),
        5 => Scenario::Custom(
            sal_pim::scenario::CustomParams::default()
                .with_config(config)
                .with_label(["lut ablation", "paper fig. 13 sanity"][rng.below(2) as usize])
                .with_param("alpha", ["0.5", "0.9"][rng.below(2) as usize])
                .with_param("n_subarrays", &format!("{}", 1 + rng.below(9))),
        ),
        _ => {
            let engines = [EngineKind::Seq, EngineKind::Batch, EngineKind::Cluster];
            let engine = engines[rng.below(3) as usize];
            let backends = [
                BackendKind::SalPim,
                BackendKind::Gpu,
                BackendKind::BankLevel,
                BackendKind::Hetero,
            ];
            let policies = [
                Policy::Fcfs,
                Policy::ShortestJobFirst,
                Policy::ShortestPromptFirst,
            ];
            let routes = [
                Routing::RoundRobin,
                Routing::LeastLoaded,
                Routing::SessionAffinity,
            ];
            // Keep the combination runnable: seq implies the SAL-PIM
            // backend and inline prefill; burst implies a rate.
            let mut p = ServeParams::default()
                .with_config(config)
                .with_engine(engine)
                .with_policy(policies[rng.below(3) as usize])
                .with_route(routes[rng.below(3) as usize])
                .with_workload(2 + rng.below(6) as usize, rng.next_u64() % 1000)
                .with_cluster(1 + rng.below(4) as usize, 2 + rng.below(8) as usize)
                .with_at_once(rng.below(2) == 0);
            if engine != EngineKind::Seq {
                p = p.with_backend(backends[rng.below(4) as usize]);
                if rng.below(2) == 0 {
                    p = p.with_prefill_chunk(Some(8 + rng.below(64) as usize));
                }
            }
            if !p.at_once && rng.below(2) == 0 {
                let rate = 10.0 + rng.below(500) as f64 + 0.5;
                let burst = if rng.below(2) == 0 {
                    Some(2 + rng.below(6) as usize)
                } else {
                    None
                };
                p = p.with_rate(Some(rate), burst);
            }
            if rng.below(4) == 0 {
                p = p.with_sweep(vec![20.0, 20.0 + rng.below(2000) as f64]);
            } else if rng.below(3) == 0 {
                // A typed workload spec supersedes the legacy arrival
                // flags (and is mutually exclusive with a load sweep).
                let specs = [
                    "poisson:120,multiturn=3:1.5",
                    "at-once,sessions=3,interactive=0.5",
                    "bursty:90:3,prefix=32:2:16,lengths=heavy:8:4:128",
                ];
                let spec = WorkloadSpec::parse(specs[rng.below(3) as usize]).unwrap();
                p = p.with_workload_spec(spec);
            }
            Scenario::Serve(p)
        }
    }
}

#[test]
fn random_scenarios_round_trip_through_toml() {
    let mut rng = SplitMix64::new(0xC0FFEE);
    for i in 0..80 {
        let scenario = rand_scenario(&mut rng);
        let text = scenario.to_toml();
        let parsed = parse_suite(&text).unwrap_or_else(|e| {
            panic!("iteration {i}: `{text}` failed to parse: {e}");
        });
        assert_eq!(parsed.len(), 1, "iteration {i}");
        assert_eq!(parsed[0], scenario, "iteration {i}: `{text}`");
    }
}

#[test]
fn random_suites_round_trip_as_a_whole() {
    let mut rng = SplitMix64::new(7);
    let suite: Vec<Scenario> = (0..10).map(|_| rand_scenario(&mut rng)).collect();
    let text = suite_to_toml(&suite);
    assert_eq!(parse_suite(&text).unwrap(), suite);
}

#[test]
fn parse_run_serialize_parse_is_stable() {
    // The satellite property: a scenario survives parse → run →
    // serialize → parse, and the run stamps the exact parameter set
    // into the outcome's provenance.
    let mut rng = SplitMix64::new(42);
    let runner = Runner::new();
    let mut ran = 0usize;
    for _ in 0..40 {
        let mut scenario = rand_scenario(&mut rng);
        // Shrink to the mini preset so the property stays fast.
        if let Scenario::Serve(p) = &mut scenario {
            p.config.preset = "mini".to_string();
            if ran >= 6 {
                continue;
            }
        } else {
            continue;
        }
        let parsed = parse_suite(&scenario.to_toml()).unwrap().remove(0);
        let outcome = runner.run(&parsed).unwrap_or_else(|e| {
            panic!("runnable-by-construction scenario failed: {e}\n{}", scenario.to_toml())
        });
        assert_eq!(outcome.schema_version, SCHEMA_VERSION);
        assert_eq!(outcome.provenance.params, parsed.to_kv());
        let again = parse_suite(&parsed.to_toml()).unwrap().remove(0);
        assert_eq!(again, parsed);
        ran += 1;
    }
    assert!(ran >= 3, "property exercised only {ran} runnable scenarios");
}

fn smoke_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("scenarios/smoke.toml")
}

#[test]
fn smoke_suite_parses_and_covers_every_family() {
    let text = std::fs::read_to_string(smoke_path()).expect("scenarios/smoke.toml is checked in");
    let suite = parse_suite(&text).unwrap();
    let kinds: Vec<&str> = suite.iter().map(|s| s.kind()).collect();
    for kind in ["simulate", "sweep", "breakdown", "power", "area", "serve"] {
        assert!(kinds.contains(&kind), "smoke suite misses `{kind}`");
    }
}

#[test]
fn smoke_suite_runs_and_writes_schema_versioned_bench_files() {
    let text = std::fs::read_to_string(smoke_path()).unwrap();
    let suite = parse_suite(&text).unwrap();
    let outcomes = Runner::new().run_suite(&suite).expect("smoke suite runs");
    assert_eq!(outcomes.len(), suite.len());

    let dir = std::env::temp_dir().join("salpim_smoke_bench");
    let _ = std::fs::remove_dir_all(&dir);
    let tagged: Vec<(&str, &sal_pim::scenario::Outcome)> = suite
        .iter()
        .zip(&outcomes)
        .map(|(s, o)| (s.bench_tag(), o))
        .collect();
    let paths = sink::write_bench_files(&dir, &tagged).unwrap();
    assert!(paths.iter().any(|p| p.ends_with("BENCH_serve.json")));
    assert!(paths.iter().any(|p| p.ends_with("BENCH_fig11.json")));
    for p in &paths {
        let body = std::fs::read_to_string(p).unwrap();
        assert!(
            body.starts_with(&format!("{{\"schema_version\": {SCHEMA_VERSION}")),
            "{}: {}",
            p.display(),
            &body[..body.len().min(80)]
        );
        assert_eq!(body.matches('{').count(), body.matches('}').count());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scenario_metrics_match_the_direct_simulation() {
    // The CLI acceptance bar: `sal-pim run`'s JSON metrics must equal
    // what the equivalent individual command computes. Both go through
    // Runner, so pin the Runner against the raw simulator here.
    let suite = parse_suite(
        "[[scenario]]\nkind = \"sweep\"\npreset = \"mini\"\nins = [8]\nouts = [4, 8]\n",
    )
    .unwrap();
    let outcome = Runner::new().run(&suite[0]).unwrap();
    let cfg = SimConfig::mini();
    let mut sim = GenerationSim::new(&cfg);
    for (row, &n_out) in outcome.rows.iter().zip(&[4usize, 8]) {
        let expect = sim.generate(8, n_out).seconds(cfg.timing.tck_ns);
        let got = row[outcome.column_index("pim").unwrap()]
            .as_f64()
            .unwrap();
        assert!(
            (got - expect).abs() < 1e-12,
            "out={n_out}: scenario {got} vs direct {expect}"
        );
    }
    // And the JSON rendering carries the same numbers (spot check).
    let json = sink::to_json(&outcome);
    assert!(json.contains("\"scenario\": \"sweep\""));
    assert!(json.contains("max_speedup"));
}
