//! Paged KV-cache invariants across the serving stack: token
//! conservation under preemption, session-reuse determinism,
//! paged-vs-whole parity at low load, and the capacity-pressure
//! throughput ordering the paging refactor exists to win.

use sal_pim::config::SimConfig;
use sal_pim::serve::workload::{requests_from_items, ArrivalPattern};
use sal_pim::serve::{
    Cluster, DeviceEngine, EvictPolicy, KvPolicy, PrefixCacheMode, Request, Routing,
    ServeMetrics, SloClass,
};
use sal_pim::testutil::RequestMix;

fn req(id: u64, session: u64, prompt: usize, out: usize, at: f64) -> Request {
    Request {
        id,
        prompt_len: prompt,
        max_new_tokens: out,
        arrival_s: at,
        session,
        slo: SloClass::Batch,
        prefix: Vec::new(),
    }
}

/// Subarrays one `tokens`-wide window pins (the whole-window unit).
fn subarrays_for(cfg: &SimConfig, tokens: usize) -> usize {
    (tokens * cfg.model.kv_bytes_per_token()).div_ceil(cfg.hbm.subarray_bytes())
}

#[test]
fn tokens_are_conserved_bit_for_bit_under_preemption() {
    // A region sized for ~2.5 full windows forces preemption with six
    // decoding requests; every request must still simulate exactly the
    // token count of an uncontended run.
    let cfg = SimConfig::paper();
    let window = 16 + 32;
    let tight = subarrays_for(&cfg, window) * 5 / 2;
    let run = |units: usize| {
        let mut eng = DeviceEngine::new(&cfg, 8)
            .with_kv_policy(KvPolicy::Paged)
            .with_kv_subarrays(units);
        for i in 0..6 {
            eng.submit(req(i, i, 16, 32, 0.0));
        }
        let mut counts: Vec<(u64, usize)> = eng
            .run()
            .iter()
            .map(|c| (c.id, c.tokens_simulated))
            .collect();
        counts.sort();
        (counts, eng.report())
    };
    let (ample_counts, ample_rep) = run(subarrays_for(&cfg, window) * 12);
    let (tight_counts, tight_rep) = run(tight);
    assert_eq!(ample_rep.preemptions, 0, "ample region must not preempt");
    assert!(tight_rep.preemptions > 0, "tight region must preempt");
    assert!(tight_rep.recompute_tokens > 0, "recompute must be charged");
    assert_eq!(
        ample_counts, tight_counts,
        "preemption must never create or destroy simulated tokens"
    );
}

#[test]
fn session_reuse_hits_are_deterministic() {
    // Two identical runs of a session-affinity cluster with follow-up
    // requests must replay reuse hits, assignments and timings exactly.
    let cfg = SimConfig::paper();
    let items = RequestMix::small(17).take(16);
    let run = || {
        let mut c = Cluster::new(&cfg, 2, 4, Routing::SessionAffinity).with_kv(
            KvPolicy::Paged,
            EvictPolicy::Lru,
            PrefixCacheMode::Session,
            None,
            None,
        );
        // 4 sessions × 4 requests each, arriving slowly enough that a
        // session's predecessor completes (and parks its blocks) before
        // the follow-up lands: plenty of reuse traffic.
        for r in requests_from_items(&items, ArrivalPattern::Jittered { scale_s: 0.5 }, 4) {
            c.submit(r);
        }
        let done = c.run();
        let finishes: Vec<(u64, u64)> = done
            .iter()
            .map(|c| (c.id, (c.finish_s * 1e12) as u64))
            .collect();
        let reuse: Vec<(usize, usize)> = c
            .per_device_reports()
            .iter()
            .map(|r| (r.reuse_hits, r.reuse_tokens))
            .collect();
        (c.assignments().to_vec(), finishes, reuse)
    };
    let (a1, f1, r1) = run();
    let (a2, f2, r2) = run();
    assert_eq!(a1, a2, "assignment drift");
    assert_eq!(f1, f2, "timing drift");
    assert_eq!(r1, r2, "reuse-hit drift");
    let total_hits: usize = r1.iter().map(|(h, _)| h).sum();
    assert!(
        total_hits > 0,
        "slow follow-up traffic on affinity routing must land reuse hits"
    );
}

#[test]
fn paged_matches_whole_bit_for_bit_at_low_load() {
    // Distinct sessions (no reuse), ample capacity, slow arrivals: the
    // paged engine must reproduce the whole-window engine's completions
    // exactly — paging only changes behaviour under pressure.
    let cfg = SimConfig::paper();
    let items = RequestMix::small(3).take(8);
    let run = |policy: KvPolicy| {
        let mut eng = DeviceEngine::new(&cfg, 4).with_kv_policy(policy);
        // One session per request: reuse can never fire.
        for (i, r) in requests_from_items(&items, ArrivalPattern::Jittered { scale_s: 0.05 }, 8)
            .into_iter()
            .enumerate()
        {
            let mut r = r;
            r.session = 100 + i as u64;
            eng.submit(r);
        }
        let mut done = eng.run();
        done.sort_by_key(|c| c.id);
        done
    };
    let whole = run(KvPolicy::Whole);
    let paged = run(KvPolicy::Paged);
    assert_eq!(whole.len(), paged.len());
    for (w, p) in whole.iter().zip(&paged) {
        assert_eq!(w.id, p.id);
        assert_eq!(w.tokens_simulated, p.tokens_simulated);
        assert_eq!(w.finish_s.to_bits(), p.finish_s.to_bits(), "request {}", w.id);
        assert_eq!(w.queue_s.to_bits(), p.queue_s.to_bits(), "request {}", w.id);
        assert_eq!(w.prefill_s.to_bits(), p.prefill_s.to_bits(), "request {}", w.id);
    }
}

#[test]
fn paged_beats_whole_under_capacity_pressure() {
    // The acceptance bar: at equal HBM capacity and saturating load the
    // paged allocator admits a strictly larger mean decode batch than
    // whole-window reservation, and throughput does not get worse.
    let cfg = SimConfig::paper();
    // Decode-heavy shape (small prompt, large budget) in a region that
    // holds ~3 whole windows: whole caps the batch at 3, paged overlaps
    // many more because only resident tokens pin blocks.
    let window = 16 + 96;
    let units = subarrays_for(&cfg, window) * 3;
    let run = |policy: KvPolicy| {
        let mut eng = DeviceEngine::new(&cfg, 12)
            .with_kv_policy(policy)
            .with_kv_subarrays(units);
        for i in 0..10 {
            eng.submit(req(i, i, 16, 96, 0.0));
        }
        let done = eng.run();
        let mut m = ServeMetrics::from_completions(&done);
        let rep = eng.report();
        m.absorb_reports(std::slice::from_ref(&rep));
        (m, rep)
    };
    let (whole_m, whole_rep) = run(KvPolicy::Whole);
    let (paged_m, paged_rep) = run(KvPolicy::Paged);
    assert_eq!(
        whole_m.total_tokens, paged_m.total_tokens,
        "token conservation across policies"
    );
    assert!(
        paged_rep.mean_decode_batch > whole_rep.mean_decode_batch,
        "paged mean batch {} !> whole {}",
        paged_rep.mean_decode_batch,
        whole_rep.mean_decode_batch
    );
    assert!(
        paged_m.throughput_tok_s >= whole_m.throughput_tok_s,
        "paged throughput {} must not trail whole {} under pressure",
        paged_m.throughput_tok_s,
        whole_m.throughput_tok_s
    );
}

#[test]
fn evict_none_is_whole_window_at_block_granularity() {
    // With eviction off, paged admission preallocates the window, so it
    // serves everything with zero preemptions even under pressure.
    let cfg = SimConfig::paper();
    let window = 16 + 32;
    let units = subarrays_for(&cfg, window) * 2;
    let mut eng = DeviceEngine::new(&cfg, 8)
        .with_kv_policy(KvPolicy::Paged)
        .with_evict(EvictPolicy::None)
        .with_kv_subarrays(units);
    for i in 0..6 {
        eng.submit(req(i, i, 16, 32, 0.0));
    }
    let done = eng.run();
    assert_eq!(done.len(), 6);
    let rep = eng.report();
    assert_eq!(rep.preemptions, 0);
    assert_eq!(rep.recompute_tokens, 0);
}

#[test]
fn kv_block_override_still_conserves_tokens() {
    // Coarser and finer blocks change packing, never token counts.
    let cfg = SimConfig::paper();
    let run = |block: Option<usize>| {
        let mut eng = DeviceEngine::new(&cfg, 8).with_kv_policy(KvPolicy::Paged);
        if let Some(b) = block {
            eng = eng.with_kv_block(b);
        }
        for i in 0..5 {
            eng.submit(req(i, i, 24, 16, 0.0));
        }
        let mut counts: Vec<(u64, usize)> = eng
            .run()
            .iter()
            .map(|c| (c.id, c.tokens_simulated))
            .collect();
        counts.sort();
        counts
    };
    let default = run(None);
    assert_eq!(default, run(Some(1)), "single-token blocks");
    assert_eq!(default, run(Some(64)), "coarse blocks");
}
