//! Scheduling-surface integration: the typed `SchedSpec` grammar
//! through the scenario API, the offline-optimal oracle bound, the
//! static-spec ⇄ legacy-flag bit-identity, and token conservation under
//! migration-heavy phase routing.

use sal_pim::scenario::{ConfigSel, EngineKind, Runner, Scenario, ServeParams};
use sal_pim::serve::{
    oracle, BackendKind, Loc, PhaseSim, PhaseTopology, Request, SchedSpec, SloClass,
};

fn mini() -> ConfigSel {
    ConfigSel::preset("mini")
}

/// The paper config (`max_seq` 1024) for the direct-`PhaseSim` tests:
/// the [`mixed`] trace's 192-token prompts would truncate against the
/// mini preset's 128-token window.
fn paper_cfg() -> sal_pim::SimConfig {
    ConfigSel::preset("paper").resolve().unwrap()
}

/// A trace whose phases disagree about the right device: even ids are
/// long-prompt/short-output (prefill-bound, GPU-friendly), odd ids are
/// short-prompt/long-output (decode-bound, PIM-friendly).
fn mixed(n: usize) -> Vec<Request> {
    (0..n as u64)
        .map(|id| {
            let (prompt_len, max_new_tokens) = if id % 2 == 0 { (192, 4) } else { (16, 48) };
            Request {
                id,
                prompt_len,
                max_new_tokens,
                arrival_s: id as f64 * 0.005,
                session: id,
                slo: SloClass::Batch,
                prefix: Vec::new(),
            }
        })
        .collect()
}

fn phase_params(spec: &str) -> ServeParams {
    ServeParams::default()
        .with_config(mini())
        .with_engine(EngineKind::Cluster)
        .with_cluster(2, 4)
        .with_workload(4, 11)
        .with_at_once(true)
        .with_schedule(SchedSpec::parse(spec).unwrap())
}

#[test]
fn every_schedule_policy_stays_within_the_oracle_bound() {
    // 4 requests keep the oracle exhaustive (4 uniforms + 4^4 per-request
    // placements + the dynamic run itself), so pct_of_oracle <= 100 is a
    // structural guarantee every policy variant must satisfy.
    for spec in [
        "phase",
        "phase,hysteresis=0",
        "phase,objective=energy",
        "phase,objective=energy,power_cap=60",
    ] {
        let out = Runner::new().run(&Scenario::Serve(phase_params(spec))).unwrap();
        let pct = out.metric_f64("pct_of_oracle").unwrap();
        assert!(pct > 0.0 && pct <= 100.0 + 1e-9, "{spec}: pct {pct}");
        let st = out.metric_f64("best_static_pct_of_oracle").unwrap();
        assert!(st > 0.0 && st <= 100.0 + 1e-9, "{spec}: static pct {st}");
        assert_eq!(out.metric_f64("oracle_candidates"), Some(261.0), "{spec}");
    }
}

#[test]
fn the_oracle_scores_itself_at_100_through_the_scenario_metrics() {
    // pct_of_oracle is oracle/achieved: re-deriving the oracle's own
    // score from the reported pair must give exactly 100 for the best
    // candidate, i.e. the two percentages share one denominator.
    let out = Runner::new().run(&Scenario::Serve(phase_params("phase"))).unwrap();
    let dynamic = out.metric_f64("pct_of_oracle").unwrap();
    let static_best = out.metric_f64("best_static_pct_of_oracle").unwrap();
    // Both are fractions of the same oracle objective; the oracle itself
    // is the max, so no candidate exceeds 100.
    assert!(dynamic.max(static_best) <= 100.0 + 1e-9);
}

#[test]
fn dynamic_routing_beats_every_uniform_static_placement_on_mixed_traffic() {
    // The PR's acceptance pin (the scenarios/phase.toml A/B pair): on a
    // trace whose phases disagree, re-deciding placement per phase must
    // land strictly closer to the oracle than the best static placement
    // — statics either serialize long prefills on the PIM pool, stall
    // short decodes on the GPU pool, or pay a migration for every
    // request.
    let cfg = paper_cfg();
    let spec = SchedSpec::parse("phase").unwrap();
    let topo = PhaseTopology::new(1, 1, 8);
    let requests = mixed(5);
    let mut sim = PhaseSim::new(&cfg, spec.clone(), topo);
    let dynamic = sim.run(&requests);
    let rep = oracle(&cfg, &spec, &topo, &requests, &[dynamic.objective]);
    assert!(rep.exhaustive, "5 requests must brute-force");
    assert!(
        dynamic.objective < rep.best_static_objective,
        "dynamic {} must beat the best static {}",
        dynamic.objective,
        rep.best_static_objective
    );
}

#[test]
fn static_schedule_specs_reproduce_legacy_backend_runs_bit_for_bit() {
    // `--schedule static:<b>` desugars onto the same engine path as
    // `--backend <b>`; the decoy legacy backend proves the spec is the
    // one steering.
    for backend in BackendKind::ALL {
        let decoy = if backend == BackendKind::Gpu {
            BackendKind::SalPim
        } else {
            BackendKind::Gpu
        };
        let legacy = ServeParams::default()
            .with_config(mini())
            .with_engine(EngineKind::Batch)
            .with_backend(backend)
            .with_workload(6, 13)
            .with_at_once(true);
        let spec = ServeParams::default()
            .with_config(mini())
            .with_engine(EngineKind::Batch)
            .with_backend(decoy)
            .with_workload(6, 13)
            .with_at_once(true)
            .with_schedule(
                SchedSpec::parse(&format!("static:{}", backend.name())).unwrap(),
            );
        let a = Runner::new().run(&Scenario::Serve(legacy)).unwrap();
        let b = Runner::new().run(&Scenario::Serve(spec)).unwrap();
        assert_eq!(a.metrics, b.metrics, "backend {}", backend.name());
        assert_eq!(a.provenance.backend, b.provenance.backend);
    }
}

#[test]
fn tokens_are_conserved_under_migration_heavy_routing() {
    // Force every request to prefill on the GPU pool and decode on the
    // PIM pool — one fabric migration each — and check the token budget
    // against a no-migration placement.
    let cfg = paper_cfg();
    let spec = SchedSpec::parse("phase").unwrap();
    let topo = PhaseTopology::new(1, 1, 8);
    let requests = mixed(5);
    let mut sim = PhaseSim::new(&cfg, spec, topo);
    sim.set_placement(Some(vec![(Loc::Gpu, Loc::Pim); requests.len()]));
    let migrating = sim.run(&requests);
    assert_eq!(migrating.router_migrations, requests.len() as u64);
    assert!(migrating.migrated_bytes > 0);
    sim.set_placement(Some(vec![(Loc::Pim, Loc::Pim); requests.len()]));
    let resident = sim.run(&requests);
    assert_eq!(resident.router_migrations, 0);
    let tokens = |cs: &[sal_pim::serve::Completion]| -> usize {
        cs.iter().map(|c| c.tokens_simulated).sum()
    };
    assert_eq!(
        tokens(&migrating.completions),
        tokens(&resident.completions),
        "migration must not create or drop tokens"
    );
    let want: usize = requests.iter().map(|r| r.max_new_tokens).sum();
    assert_eq!(tokens(&migrating.completions), want);
}
