//! End-to-end serving driver: the full three-layer stack on a real small
//! workload.
//!
//! * loads the AOT-compiled GPT-2-mini HLO artifacts (JAX L2 + Pallas L1,
//!   built once by `make artifacts`) through the PJRT runtime — Python is
//!   not involved at run time;
//! * decodes every request's tokens through BOTH the float golden model
//!   (PJRT) and the bit-exact fixed-point functional pipeline (the
//!   S-ALU/LUT path), cross-checking them token by token;
//! * runs the request batch through the serving coordinator, attributing
//!   cycle-accurate SAL-PIM latency (GPT-2-medium timing) per request;
//! * reports per-request latency, throughput, and speedup vs the GPU
//!   baseline. Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_textgen
//! ```

use sal_pim::baseline::GpuModel;
use sal_pim::config::SimConfig;
use sal_pim::coordinator::{Coordinator, Policy, ServeMetrics};
use sal_pim::model::FunctionalGpt;
use sal_pim::report::{fmt_time, fmt_x, Table};
use sal_pim::runtime::{artifacts_available, default_artifacts_dir, GoldenGpt, Runtime};
use sal_pim::testutil::SplitMix64;

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    anyhow::ensure!(
        artifacts_available(&dir),
        "artifacts missing — run `make artifacts` first"
    );

    // ---- Functional path: real tokens through PJRT + fixed point ----
    let rt = Runtime::new()?;
    let mut golden = GoldenGpt::load(&rt, &dir, false)?;
    let mut fixed = FunctionalGpt::new(&SimConfig::mini());

    let mut rng = SplitMix64::new(7);
    let requests: Vec<(Vec<usize>, usize)> = (0..6)
        .map(|_| {
            let plen = 3 + rng.below(6) as usize;
            let prompt: Vec<usize> = (0..plen).map(|_| rng.below(256) as usize).collect();
            let n_out = 4 + rng.below(12) as usize;
            (prompt, n_out)
        })
        .collect();

    println!("== functional serving: PJRT golden vs fixed-point PIM pipeline ==");
    let mut agree = 0usize;
    let mut total = 0usize;
    for (i, (prompt, n_out)) in requests.iter().enumerate() {
        let a = golden.generate(prompt, *n_out)?;
        fixed.reset();
        let b = fixed.generate(prompt, *n_out);
        let hits = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        agree += hits;
        total += a.len();
        println!(
            "  req {i}: prompt {:>2} tok → {:>2} out | golden {:?} | match {}/{}",
            prompt.len(),
            n_out,
            &a[..a.len().min(6)],
            hits,
            a.len()
        );
    }
    let agreement = agree as f64 / total as f64;
    println!("token agreement (float vs fixed-point PIM): {:.1}%", agreement * 100.0);
    anyhow::ensure!(agreement > 0.8, "pipelines diverged: {agreement}");

    // ---- Timing path: the same request mix on the cycle-accurate ----
    // ---- GPT-2-medium device, FCFS vs SJF vs GPU baseline.        ----
    println!("\n== cycle-accurate serving (GPT-2 medium timing) ==");
    let cfg = SimConfig::paper();
    let mut table = Table::new(
        "serving policies (16 requests, arrivals over ~0.4 s)",
        &["policy", "throughput", "p50 latency", "p95 latency", "p95 TTFT"],
    );
    let mut makespans = Vec::new();
    for policy in [Policy::Fcfs, Policy::ShortestJobFirst] {
        let mut coord = Coordinator::new(&cfg).with_policy(policy);
        let mut rng = SplitMix64::new(42);
        let mut at = 0.0;
        for _ in 0..16 {
            let prompt = 16 + (rng.below(8) * 16) as usize;
            let out = 8 << rng.below(5) as usize;
            at += rng.f64_unit() * 0.05;
            coord.submit(prompt, out, at);
        }
        let done = coord.run();
        let m = ServeMetrics::from_completions(&done);
        makespans.push((m.makespan_s, m.total_tokens));
        table.row(&[
            policy.name().into(),
            format!("{:.1} tok/s", m.throughput_tok_s),
            fmt_time(m.p50_latency_s),
            fmt_time(m.p95_latency_s),
            fmt_time(m.p95_ttft_s),
        ]);
    }
    table.print();

    // GPU baseline on the same workload (sequential FCFS service).
    let gpu = GpuModel::titan_rtx();
    let mut rng = SplitMix64::new(42);
    let mut gpu_time = 0.0;
    for _ in 0..16 {
        let prompt = 16 + (rng.below(8) * 16) as usize;
        let out = 8 << rng.below(5) as usize;
        let _jitter = rng.f64_unit(); // keep the RNG stream aligned
        gpu_time += gpu.generation_time(&cfg.model, prompt, out);
    }
    let (pim_makespan, tokens) = makespans[0];
    println!(
        "GPU serial service time: {} | SAL-PIM makespan: {} | speedup {}",
        fmt_time(gpu_time),
        fmt_time(pim_makespan),
        fmt_x(gpu_time / pim_makespan)
    );
    println!("served {tokens} tokens end-to-end — all layers composed (L1 Pallas → L2 JAX → PJRT → L3 coordinator)");
    Ok(())
}
