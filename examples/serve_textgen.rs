//! End-to-end serving driver: the full stack on a real small workload.
//!
//! * (with `--features pjrt` and `make artifacts`) decodes every
//!   request's tokens through BOTH the float golden model (PJRT) and the
//!   bit-exact fixed-point functional pipeline (the S-ALU/LUT path),
//!   cross-checking them token by token;
//! * draws ONE request mix ([`RequestMix`]) and serves it through three
//!   engines side by side — the sequential coordinator, the
//!   continuous-batching engine and a 4-device cluster — plus the GPU
//!   baseline, all consuming the identical workload by construction;
//! * serves the same mix on three *execution backends* — SAL-PIM, the
//!   batched GPU roofline, and heterogeneous GPU-prefill + PIM-decode
//!   (with chunked prefill) — the paper-style end-to-end comparison
//!   under load;
//! * reports throughput, latency percentiles and speedups.
//!
//! ```bash
//! cargo run --release --example serve_textgen
//! make artifacts && cargo run --release --features pjrt --example serve_textgen
//! ```

use sal_pim::baseline::GpuModel;
use sal_pim::config::SimConfig;
use sal_pim::coordinator::{Coordinator, Policy, ServeMetrics};
use sal_pim::report::{fmt_pct, fmt_time, fmt_x, Table};
use sal_pim::serve::workload::{requests_from_items, ArrivalPattern};
use sal_pim::serve::{BackendKind, Cluster, DeviceEngine, Routing};
use sal_pim::testutil::{MixItem, RequestMix};

/// Float-golden (PJRT) vs fixed-point cross-check — needs the `pjrt`
/// feature and `make artifacts`.
#[cfg(feature = "pjrt")]
fn golden_crosscheck() -> anyhow::Result<()> {
    use sal_pim::model::FunctionalGpt;
    use sal_pim::runtime::{artifacts_available, default_artifacts_dir, GoldenGpt, Runtime};
    use sal_pim::testutil::SplitMix64;

    let dir = default_artifacts_dir();
    anyhow::ensure!(
        artifacts_available(&dir),
        "artifacts missing — run `make artifacts` first"
    );
    let rt = Runtime::new()?;
    let mut golden = GoldenGpt::load(&rt, &dir, false)?;
    let mut fixed = FunctionalGpt::new(&SimConfig::mini());

    let mut rng = SplitMix64::new(7);
    let requests: Vec<(Vec<usize>, usize)> = (0..6)
        .map(|_| {
            let plen = 3 + rng.below(6) as usize;
            let prompt: Vec<usize> = (0..plen).map(|_| rng.below(256) as usize).collect();
            let n_out = 4 + rng.below(12) as usize;
            (prompt, n_out)
        })
        .collect();

    println!("== functional serving: PJRT golden vs fixed-point PIM pipeline ==");
    let mut agree = 0usize;
    let mut total = 0usize;
    for (i, (prompt, n_out)) in requests.iter().enumerate() {
        let a = golden.generate(prompt, *n_out)?;
        fixed.reset();
        let b = fixed.generate(prompt, *n_out);
        let hits = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        agree += hits;
        total += a.len();
        println!(
            "  req {i}: prompt {:>2} tok → {:>2} out | golden {:?} | match {}/{}",
            prompt.len(),
            n_out,
            &a[..a.len().min(6)],
            hits,
            a.len()
        );
    }
    let agreement = agree as f64 / total as f64;
    println!(
        "token agreement (float vs fixed-point PIM): {:.1}%",
        agreement * 100.0
    );
    anyhow::ensure!(agreement > 0.8, "pipelines diverged: {agreement}");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    #[cfg(feature = "pjrt")]
    golden_crosscheck()?;
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt feature disabled — skipping the float golden cross-check)");

    // ---- Timing path: ONE request mix served by every engine.       ----
    // The mix is drawn once as data, so the coordinator, the batching
    // engine, the cluster and the GPU baseline consume the identical
    // workload — no RNG-stream-alignment tricks.
    println!("\n== cycle-accurate serving (GPT-2 medium timing, 16 requests) ==");
    let cfg = SimConfig::paper();
    let items: Vec<MixItem> = RequestMix::paper(42).take(16);
    let pattern = ArrivalPattern::Jittered { scale_s: 0.05 };

    let mut table = Table::new(
        "serving engines on the shared 16-request mix (arrivals over ~0.4 s)",
        &["engine", "throughput", "p50 latency", "p95 latency", "p95 TTFT"],
    );
    let mut seq_metrics = None;

    for policy in [Policy::Fcfs, Policy::ShortestJobFirst] {
        let mut coord = Coordinator::new(&cfg).with_policy(policy);
        for r in requests_from_items(&items, pattern, 8) {
            coord.submit_request(r);
        }
        let m = ServeMetrics::from_completions(&coord.run());
        table.row(&[
            format!("sequential {}", policy.name()),
            format!("{:.1} tok/s", m.throughput_tok_s),
            fmt_time(m.p50_latency_s),
            fmt_time(m.p95_latency_s),
            fmt_time(m.p95_ttft_s),
        ]);
        if policy == Policy::Fcfs {
            seq_metrics = Some(m);
        }
    }

    let mut engine = DeviceEngine::new(&cfg, 8);
    for r in requests_from_items(&items, pattern, 8) {
        engine.submit(r);
    }
    let batch_m = ServeMetrics::from_completions(&engine.run());
    let rep = engine.report();
    table.row(&[
        "continuous batch×8".into(),
        format!("{:.1} tok/s", batch_m.throughput_tok_s),
        fmt_time(batch_m.p50_latency_s),
        fmt_time(batch_m.p95_latency_s),
        fmt_time(batch_m.p95_ttft_s),
    ]);

    let mut cluster = Cluster::new(&cfg, 4, 8, Routing::RoundRobin);
    for r in requests_from_items(&items, pattern, 8) {
        cluster.submit(r);
    }
    let cluster_m = ServeMetrics::from_completions(&cluster.run());
    table.row(&[
        "cluster 4×batch8".into(),
        format!("{:.1} tok/s", cluster_m.throughput_tok_s),
        fmt_time(cluster_m.p50_latency_s),
        fmt_time(cluster_m.p95_latency_s),
        fmt_time(cluster_m.p95_ttft_s),
    ]);
    table.print();

    println!(
        "batching engine: kv peak util {} | max batch seen {}",
        fmt_pct(rep.kv_peak_utilization),
        rep.max_batch_seen
    );

    // ---- Execution backends: SAL-PIM vs GPU vs hetero, one device  ----
    // each, continuous batch×8, the IDENTICAL request mix. The hetero
    // device runs GPU prefill + PIM decode with a PCIe-class KV handoff,
    // with prefill interleaved in 32-token chunks.
    let mut bt = Table::new(
        "execution backends (continuous batch×8, identical 16-request mix)",
        &["backend", "throughput", "p50 latency", "p95 latency", "p95 TTFT", "makespan"],
    );
    let mut backend_makespans: Vec<(BackendKind, f64)> = Vec::new();
    for kind in [BackendKind::SalPim, BackendKind::Gpu, BackendKind::Hetero] {
        let chunk = if kind == BackendKind::Hetero { Some(32) } else { None };
        let mut eng = DeviceEngine::with_backend(kind.build(&cfg), 8).with_prefill_chunk(chunk);
        for r in requests_from_items(&items, pattern, 8) {
            eng.submit(r);
        }
        let name = eng.backend_name();
        let m = ServeMetrics::from_completions(&eng.run());
        bt.row(&[
            name,
            format!("{:.1} tok/s", m.throughput_tok_s),
            fmt_time(m.p50_latency_s),
            fmt_time(m.p95_latency_s),
            fmt_time(m.p95_ttft_s),
            fmt_time(m.makespan_s),
        ]);
        backend_makespans.push((kind, m.makespan_s));
    }
    bt.print();
    let span = |k: BackendKind| {
        backend_makespans
            .iter()
            .find(|(kind, _)| *kind == k)
            .map(|(_, s)| *s)
            .expect("backend row recorded")
    };
    println!(
        "speedup vs GPU backend on the served mix: sal-pim {} | hetero {}",
        fmt_x(span(BackendKind::Gpu) / span(BackendKind::SalPim)),
        fmt_x(span(BackendKind::Gpu) / span(BackendKind::Hetero))
    );

    // GPU baseline on the same workload (sequential FCFS service) —
    // identical mix, by construction.
    let gpu = GpuModel::titan_rtx();
    let gpu_time: f64 = items
        .iter()
        .map(|it| gpu.generation_time(&cfg.model, it.prompt_len, it.max_new_tokens))
        .sum();
    let seq = seq_metrics.expect("fcfs row recorded");
    println!(
        "GPU serial service time: {} | sequential PIM makespan: {} (speedup {}) | batched: {} (speedup {})",
        fmt_time(gpu_time),
        fmt_time(seq.makespan_s),
        fmt_x(gpu_time / seq.makespan_s),
        fmt_time(batch_m.makespan_s),
        fmt_x(gpu_time / batch_m.makespan_s)
    );
    println!(
        "served {} tokens per engine — sequential vs continuous batching vs 4-device cluster",
        seq.total_tokens
    );
    Ok(())
}
