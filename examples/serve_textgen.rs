//! End-to-end serving driver: the full stack on a real small workload.
//!
//! * (with `--features pjrt` and `make artifacts`) decodes every
//!   request's tokens through BOTH the float golden model (PJRT) and the
//!   bit-exact fixed-point functional pipeline (the S-ALU/LUT path),
//!   cross-checking them token by token;
//! * declares ONE shared workload (16 requests, seed 42, jittered
//!   arrivals) as `Scenario::Serve` descriptions and runs it through the
//!   scenario `Runner` on four engines side by side — the sequential
//!   coordinator (fcfs and sjf), the continuous-batching engine and a
//!   4-device cluster — every engine consuming the identical mix by
//!   construction;
//! * serves the same mix on three *execution backends* — SAL-PIM, the
//!   batched GPU roofline, and heterogeneous GPU-prefill + PIM-decode
//!   (with chunked prefill) — the paper-style end-to-end comparison
//!   under load;
//! * reports throughput, latency percentiles and speedups from the
//!   structured outcomes.
//!
//! ```bash
//! cargo run --release --example serve_textgen
//! make artifacts && cargo run --release --features pjrt --example serve_textgen
//! ```

use sal_pim::baseline::GpuModel;
use sal_pim::config::SimConfig;
use sal_pim::report::{fmt_time, fmt_x, Table};
use sal_pim::scenario::{EngineKind, Outcome, Runner, Scenario, ServeParams};
use sal_pim::serve::{BackendKind, Policy};
use sal_pim::testutil::{MixItem, RequestMix};

/// Float-golden (PJRT) vs fixed-point cross-check — needs the `pjrt`
/// feature and `make artifacts`.
#[cfg(feature = "pjrt")]
fn golden_crosscheck() -> anyhow::Result<()> {
    use sal_pim::model::FunctionalGpt;
    use sal_pim::runtime::{artifacts_available, default_artifacts_dir, GoldenGpt, Runtime};
    use sal_pim::testutil::SplitMix64;

    let dir = default_artifacts_dir();
    anyhow::ensure!(
        artifacts_available(&dir),
        "artifacts missing — run `make artifacts` first"
    );
    let rt = Runtime::new()?;
    let mut golden = GoldenGpt::load(&rt, &dir, false)?;
    let mut fixed = FunctionalGpt::new(&SimConfig::mini());

    let mut rng = SplitMix64::new(7);
    let requests: Vec<(Vec<usize>, usize)> = (0..6)
        .map(|_| {
            let plen = 3 + rng.below(6) as usize;
            let prompt: Vec<usize> = (0..plen).map(|_| rng.below(256) as usize).collect();
            let n_out = 4 + rng.below(12) as usize;
            (prompt, n_out)
        })
        .collect();

    println!("== functional serving: PJRT golden vs fixed-point PIM pipeline ==");
    let mut agree = 0usize;
    let mut total = 0usize;
    for (i, (prompt, n_out)) in requests.iter().enumerate() {
        let a = golden.generate(prompt, *n_out)?;
        fixed.reset();
        let b = fixed.generate(prompt, *n_out);
        let hits = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        agree += hits;
        total += a.len();
        println!(
            "  req {i}: prompt {:>2} tok → {:>2} out | golden {:?} | match {}/{}",
            prompt.len(),
            n_out,
            &a[..a.len().min(6)],
            hits,
            a.len()
        );
    }
    let agreement = agree as f64 / total as f64;
    println!(
        "token agreement (float vs fixed-point PIM): {:.1}%",
        agreement * 100.0
    );
    anyhow::ensure!(agreement > 0.8, "pipelines diverged: {agreement}");
    Ok(())
}

/// One row of a cross-engine comparison table, from outcome metrics.
fn metrics_row(label: &str, o: &Outcome) -> Vec<String> {
    vec![
        label.to_string(),
        format!("{:.1} tok/s", o.metric_f64("throughput").unwrap()),
        fmt_time(o.metric_f64("p50_latency").unwrap()),
        fmt_time(o.metric_f64("p95_latency").unwrap()),
        fmt_time(o.metric_f64("p95_ttft").unwrap()),
    ]
}

fn main() -> anyhow::Result<()> {
    #[cfg(feature = "pjrt")]
    golden_crosscheck()?;
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt feature disabled — skipping the float golden cross-check)");

    // ---- Timing path: ONE workload declaration, every engine.       ----
    // The shared base scenario (16 requests, seed 42, jittered arrivals)
    // pins the workload; engines vary around it, so the coordinator, the
    // batching engine, the cluster and the GPU baseline all consume the
    // identical request mix by construction.
    println!("\n== cycle-accurate serving (GPT-2 medium timing, 16 requests) ==");
    let runner = Runner::new();
    let base = ServeParams::default().with_workload(16, 42);
    let run = |p: ServeParams| -> anyhow::Result<Outcome> {
        Ok(runner.run(&Scenario::Serve(p))?)
    };

    let seq_fcfs = run(base.clone())?;
    let seq_sjf = run(base.clone().with_policy(Policy::ShortestJobFirst))?;
    let batch = run(base.clone().with_engine(EngineKind::Batch))?;
    let cluster = run(base
        .clone()
        .with_engine(EngineKind::Cluster)
        .with_cluster(4, 8))?;

    let mut table = Table::new(
        "serving engines on the shared 16-request mix (arrivals over ~0.4 s)",
        &["engine", "throughput", "p50 latency", "p95 latency", "p95 TTFT"],
    );
    table.row(&metrics_row("sequential fcfs", &seq_fcfs));
    table.row(&metrics_row("sequential sjf", &seq_sjf));
    table.row(&metrics_row("continuous batch×8", &batch));
    table.row(&metrics_row("cluster 4×batch8", &cluster));
    table.print();

    println!(
        "batching engine: kv peak util {:.1}% | max batch seen {}",
        batch.metric_f64("kv_peak_utilization").unwrap() * 100.0,
        batch.metric_f64("max_batch_seen").unwrap()
    );

    // ---- Execution backends: SAL-PIM vs GPU vs hetero, one device  ----
    // each, continuous batch×8, the IDENTICAL request mix. The hetero
    // device runs GPU prefill + PIM decode with a PCIe-class KV handoff,
    // with prefill interleaved in 32-token chunks.
    let mut bt = Table::new(
        "execution backends (continuous batch×8, identical 16-request mix)",
        &["backend", "throughput", "p50 latency", "p95 latency", "p95 TTFT", "makespan"],
    );
    let mut backend_makespans: Vec<(BackendKind, f64)> = Vec::new();
    for kind in [BackendKind::SalPim, BackendKind::Gpu, BackendKind::Hetero] {
        let chunk = if kind == BackendKind::Hetero { Some(32) } else { None };
        let o = run(base
            .clone()
            .with_engine(EngineKind::Batch)
            .with_backend(kind)
            .with_prefill_chunk(chunk))?;
        let makespan = o.metric_f64("makespan").unwrap();
        let mut row = metrics_row(kind.name(), &o);
        row.push(fmt_time(makespan));
        bt.row(&row);
        backend_makespans.push((kind, makespan));
    }
    bt.print();
    let span = |k: BackendKind| {
        backend_makespans
            .iter()
            .find(|(kind, _)| *kind == k)
            .map(|(_, s)| *s)
            .expect("backend row recorded")
    };
    println!(
        "speedup vs GPU backend on the served mix: sal-pim {} | hetero {}",
        fmt_x(span(BackendKind::Gpu) / span(BackendKind::SalPim)),
        fmt_x(span(BackendKind::Gpu) / span(BackendKind::Hetero))
    );

    // GPU baseline on the same workload (sequential FCFS service) —
    // identical mix, by construction: the scenario draws its items from
    // `RequestMix::paper(seed)` exactly as done here.
    let cfg = SimConfig::paper();
    let items: Vec<MixItem> = RequestMix::paper(42).take(16);
    let gpu = GpuModel::titan_rtx();
    let gpu_time: f64 = items
        .iter()
        .map(|it| gpu.generation_time(&cfg.model, it.prompt_len, it.max_new_tokens))
        .sum();
    let seq_makespan = seq_fcfs.metric_f64("makespan").unwrap();
    let batch_makespan = batch.metric_f64("makespan").unwrap();
    println!(
        "GPU serial service time: {} | sequential PIM makespan: {} (speedup {}) | batched: {} (speedup {})",
        fmt_time(gpu_time),
        fmt_time(seq_makespan),
        fmt_x(gpu_time / seq_makespan),
        fmt_time(batch_makespan),
        fmt_x(gpu_time / batch_makespan)
    );
    println!(
        "served {} tokens per engine — sequential vs continuous batching vs 4-device cluster",
        seq_fcfs.metric_f64("total_tokens").unwrap() as usize
    );
    Ok(())
}
