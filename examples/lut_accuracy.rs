//! LUT interpolation error explorer: per-function error vs section count
//! and the bit-exact fixed-point evaluation pipeline (Fig. 4 companion).
//!
//! ```bash
//! cargo run --release --example lut_accuracy [sections]
//! ```

use sal_pim::interp::{max_abs_error, mean_abs_error, LutTable, NonLinFn};
use sal_pim::model::fixedpoint::Q8_8;
use sal_pim::report::Table;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let sections: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);

    let mut t = Table::new(
        &format!("LUT interpolation error at {sections} sections"),
        &["function", "range", "max err", "mean err", "shift decode"],
    );
    for f in NonLinFn::ALL {
        let table = LutTable::build(f, sections, Q8_8, Q8_8);
        t.row(&[
            f.name().into(),
            format!("[{}, {})", table.lo, table.hi),
            format!("{:.5}", max_abs_error(&table, 4096)),
            format!("{:.5}", mean_abs_error(&table, 4096)),
            format!(">> {}", table.index_shift),
        ]);
    }
    t.print();

    // Show the integer pipeline on a few GELU inputs.
    let g = LutTable::build(NonLinFn::Gelu, sections, Q8_8, Q8_8);
    println!("GELU fixed-point pipeline (x → section → W·x+B):");
    for x in [-2.0f64, -0.5, 0.0, 0.5, 2.0] {
        let raw = Q8_8.quantize(x);
        let sec = g.section_of(raw);
        let y = g.eval_raw(raw);
        println!(
            "  x={x:>5.2} raw={raw:>6} section={sec:>2} → y_raw={y:>6} ({:.4} vs exact {:.4})",
            Q8_8.dequantize(y),
            NonLinFn::Gelu.eval_exact(x)
        );
    }
}
