//! Quickstart: simulate one GPT-2-medium decode iteration on SAL-PIM and
//! compare against the GPU baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sal_pim::baseline::GpuModel;
use sal_pim::config::SimConfig;
use sal_pim::mapper::GenerationSim;
use sal_pim::report::{fmt_bw, fmt_time, fmt_x};

fn main() {
    // The paper's Table 2 configuration: HBM2, P_Sub = 4, GPT-2 medium.
    let cfg = SimConfig::paper();
    let mut sim = GenerationSim::new(&cfg);

    // One decode iteration with a 128-token KV context.
    let stats = sim.decode_token(128);
    let secs = stats.seconds(cfg.timing.tck_ns);
    println!("SAL-PIM decode iteration: {}", fmt_time(secs));
    println!(
        "  achieved internal bandwidth: {}",
        fmt_bw(stats.avg_internal_bandwidth(cfg.timing.tck_ns) * cfg.hbm.pseudo_channels() as f64)
    );
    for (phase, frac) in stats.breakdown() {
        println!("  {:>13}: {:5.2}%", phase.name(), frac * 100.0);
    }

    // The same iteration on the calibrated Titan RTX baseline.
    let gpu = GpuModel::titan_rtx().decode_token_time(&cfg.model, 128);
    println!("GPU decode iteration:     {}", fmt_time(gpu));
    println!("speedup: {}", fmt_x(gpu / secs));
}
