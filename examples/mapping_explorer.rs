//! Mapping explorer: sweep the (P_Ch, P_Ba, P_Sub) data-mapping space
//! for a GEMV and print achieved bandwidth/utilization — the Fig. 6
//! design space as a runnable tool.
//!
//! ```bash
//! cargo run --release --example mapping_explorer [rows] [cols]
//! ```

use sal_pim::config::SimConfig;
use sal_pim::mapper::{gemv_geometry, map_gemv};
use sal_pim::pim::PimEngine;
use sal_pim::report::{fmt_bw, fmt_time, Table};
use sal_pim::stats::Phase;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let rows: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4096);
    let cols: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1024);

    let mut t = Table::new(
        &format!("GEMV {rows}×{cols} mapping sweep"),
        &["P_Sub", "P_Ba", "groups", "bursts/grp", "time", "device bw", "util %"],
    );
    for p_sub in [1usize, 2, 4] {
        for p_ba in [4usize, 8, 16] {
            let mut cfg = SimConfig::paper().with_p_sub(p_sub);
            cfg.parallelism.p_ba = p_ba;
            let g = gemv_geometry(&cfg, rows, cols);
            let mut e = PimEngine::new(&cfg);
            let st = e.execute(&map_gemv(&cfg, rows, cols, Phase::Ffn)).unwrap();
            let secs = st.seconds(cfg.timing.tck_ns);
            let bw = st.avg_internal_bandwidth(cfg.timing.tck_ns)
                * cfg.hbm.pseudo_channels() as f64;
            let util = bw / cfg.peak_internal_bandwidth() * 100.0;
            t.row(&[
                p_sub.to_string(),
                p_ba.to_string(),
                g.groups.to_string(),
                g.bursts_per_group.to_string(),
                fmt_time(secs),
                fmt_bw(bw),
                format!("{util:.0}"),
            ]);
        }
    }
    t.print();
    println!(
        "The paper's choice — rows→(P_Ch,P_Sub), cols→P_Ba with C-ALU merge —\n\
         is the row with P_Sub=4, P_Ba=16 (Fig. 6(b))."
    );
}
